//! Coordinator tests: pipeline invariants, router behaviour, batcher
//! accounting.

use super::*;
use crate::compute::CpuBackend;
use crate::coordinator::jobs::MatrixPayload;
use crate::linalg::Mat;
use crate::rng::rng;
use crate::sketch::SketchKind;
use crate::spsd::{DenseKernelOracle, KernelOracle, RbfOracle};
use crate::svdstream::fast::{fast_sp_svd_with, FastSpSvdSketches};
use crate::svdstream::source::DenseColumnStream;
use crate::svdstream::FastSpSvdConfig;
use crate::testing::assert_close;

fn test_matrix(m: usize, n: usize, seed: u64) -> Mat {
    let mut r = rng(seed);
    crate::data::synth_dense(m, n, 20, crate::data::SpectrumKind::Exponential { base: 0.8 }, 0.05, &mut r)
}

/// The concurrent pipeline must produce exactly the single-threaded
/// reference result given the same sketches (all updates commute).
#[test]
fn pipeline_matches_reference() {
    let a = test_matrix(120, 100, 1);
    let cfg = FastSpSvdConfig::paper(5, 4, SketchKind::Gaussian);
    let mut r = rng(2);
    let sketches = FastSpSvdSketches::draw(&cfg, 120, 100, &mut r);

    let mut ref_stream = DenseColumnStream::new(&a, 16);
    let reference = fast_sp_svd_with(&mut ref_stream, &cfg, &sketches).unwrap();

    for workers in [1usize, 3] {
        let pipeline = StreamPipeline::new(PipelineConfig {
            workers,
            queue_depth: 2,
            ..PipelineConfig::default()
        });
        // OnePassStream panics on any replay: the SVD pipeline must be
        // single-pass just like the CUR one.
        let mut stream = crate::svdstream::OnePassStream::new(DenseColumnStream::new(&a, 16));
        let result = pipeline.run(&mut stream, &cfg, &sketches).unwrap();
        assert_eq!(result.blocks, stream.blocks());
        assert_close(&result.u, &reference.u, 1e-8, &format!("U ({workers} workers)"));
        assert_close(&result.v, &reference.v, 1e-8, &format!("V ({workers} workers)"));
        for (a_, b_) in result.sigma.iter().zip(&reference.sigma) {
            assert!((a_ - b_).abs() < 1e-8);
        }
        assert_eq!(result.blocks, reference.blocks);
    }
}

/// The concurrent streaming-CUR pipeline must be *bitwise* identical to
/// the single-threaded reference for every worker count: the fold is
/// driver-side in stream order and the Gaussian applies are bitwise, so
/// nothing may drift — indices, retained columns, core, resolved rows.
#[test]
fn pipeline_cur_matches_reference_bitwise() {
    let a = test_matrix(150, 180, 20);
    let cfg = crate::cur::StreamingCurConfig::fast(12, 12, 8, 3);
    let mut rs = rng(31);
    let sketches = crate::cur::StreamingCurSketches::draw(&cfg, 150, 180, &mut rs);

    let mut ref_stream = DenseColumnStream::new(&a, 48);
    let mut r1 = rng(32);
    let reference = crate::cur::streaming_cur_with(&mut ref_stream, &cfg, &sketches, &mut r1);

    for workers in [1usize, 3] {
        let pipeline = StreamPipeline::new(PipelineConfig {
            workers,
            queue_depth: 4,
            ..PipelineConfig::default()
        });
        let mut stream = crate::svdstream::OnePassStream::new(DenseColumnStream::new(&a, 48));
        let mut r2 = rng(32);
        let result = pipeline.run_cur(&mut stream, &cfg, &sketches, &mut r2).unwrap();
        assert_eq!(result.blocks, reference.blocks);
        assert_eq!(result.blocks, stream.blocks());
        assert_eq!(result.candidates, reference.candidates);
        assert_eq!(
            result.cur.col_idx,
            reference.cur.col_idx,
            "column selection drifted at {workers} workers"
        );
        assert_eq!(
            result.cur.row_idx,
            reference.cur.row_idx,
            "row selection drifted at {workers} workers"
        );
        assert_eq!(result.cur.c.data(), reference.cur.c.data());
        assert_eq!(result.cur.u.data(), reference.cur.u.data());
        assert_eq!(result.cur.r.data(), reference.cur.r.data());
        assert_eq!(pipeline.metrics.get("pipeline.cur_blocks"), reference.blocks as u64);
        assert_eq!(pipeline.metrics.get("pipeline.cur_cols"), 180);
        assert_eq!(
            pipeline.metrics.get("pipeline.cur_reservoir_candidates"),
            reference.candidates as u64
        );
    }
}

/// Every block is processed exactly once and backpressure bounds the
/// in-flight queue depth.
#[test]
fn pipeline_processes_each_block_once_with_bounded_queue() {
    let a = test_matrix(60, 90, 3);
    let cfg = FastSpSvdConfig::paper(4, 3, SketchKind::Gaussian);
    let mut r = rng(4);
    let sketches = FastSpSvdSketches::draw(&cfg, 60, 90, &mut r);
    let depth = 3;
    let pipeline = StreamPipeline::new(PipelineConfig {
        workers: 2,
        queue_depth: depth,
        ..PipelineConfig::default()
    });
    let mut stream = DenseColumnStream::new(&a, 8);
    let result = pipeline.run(&mut stream, &cfg, &sketches).unwrap();
    let expected_blocks = (90 + 7) / 8;
    assert_eq!(result.blocks, expected_blocks);
    assert_eq!(pipeline.metrics.get("pipeline.blocks"), expected_blocks as u64);
    assert_eq!(pipeline.metrics.get("pipeline.blocks_sent"), expected_blocks as u64);
    assert_eq!(pipeline.metrics.get("pipeline.cols"), 90);
    // Batch design: at most `workers` blocks are ever in flight (the
    // metric records the largest batch), tighter than the old channel's
    // `depth + workers` bound.
    assert!(
        pipeline.max_queue_depth() <= 2,
        "in-flight blocks {} exceeded the `workers` bound",
        pipeline.max_queue_depth()
    );
}

#[test]
fn router_executes_all_job_kinds() {
    let router = Router::new(2);
    let a = test_matrix(80, 60, 5);
    let mut r = rng(6);
    let g_c = Mat::randn(60, 6, &mut r);
    let c = crate::linalg::matmul(&a, &g_c);
    let g_r = Mat::randn(5, 80, &mut r);
    let rr = crate::linalg::matmul(&g_r, &a);

    let h1 = router
        .submit(ApproxJob::Gmr {
            a: MatrixPayload::Dense(a.clone()),
            c: c.clone(),
            r: rr.clone(),
            cfg: crate::gmr::FastGmrConfig::gaussian(48, 40),
            seed: 7,
        })
        .unwrap();
    let h2 = router
        .submit(ApproxJob::GmrExact {
            a: MatrixPayload::Dense(a.clone()),
            c: c.clone(),
            r: rr.clone(),
        })
        .unwrap();
    let x_pts = Mat::randn(100, 6, &mut r);
    let h3 = router
        .submit(ApproxJob::SpsdKernel { x: x_pts, sigma: 0.4, c: 8, s: 40, seed: 8 })
        .unwrap();
    let h4 = router
        .submit(ApproxJob::StreamSvd {
            a: MatrixPayload::Dense(a.clone()),
            cfg: FastSpSvdConfig::paper(4, 3, SketchKind::Gaussian),
            block: 16,
            seed: 9,
        })
        .unwrap();
    let h5 = router
        .submit(ApproxJob::Cur {
            a: MatrixPayload::Dense(a.clone()),
            cfg: crate::cur::CurConfig::fast(9, 7, 3),
            seed: 10,
        })
        .unwrap();
    let h6 = router
        .submit(ApproxJob::StreamingCur {
            a: MatrixPayload::Dense(a.clone()),
            cfg: crate::cur::StreamingCurConfig::fast(9, 7, 4, 3),
            block: 16,
            seed: 11,
        })
        .unwrap();

    match h1.wait().unwrap() {
        JobResult::Gmr { x } => assert_eq!(x.shape(), (6, 5)),
        _ => panic!("wrong result kind"),
    }
    match h2.wait().unwrap() {
        JobResult::Gmr { x } => assert_eq!(x.shape(), (6, 5)),
        _ => panic!("wrong result kind"),
    }
    match h3.wait().unwrap() {
        JobResult::Spsd { idx, c, x, entries_observed } => {
            assert_eq!(idx.len(), 8);
            assert_eq!(c.shape(), (100, 8));
            assert_eq!(x.shape(), (8, 8));
            assert_eq!(entries_observed, 100 * 8 + 40 * 40);
        }
        _ => panic!("wrong result kind"),
    }
    match h4.wait().unwrap() {
        JobResult::Svd { u, sigma, v } => {
            assert_eq!(u.rows(), 80);
            assert_eq!(v.rows(), 60);
            assert!(!sigma.is_empty());
        }
        _ => panic!("wrong result kind"),
    }
    match h5.wait().unwrap() {
        JobResult::Cur { cur } => {
            assert_eq!(cur.c.shape(), (80, 9));
            assert_eq!(cur.u.shape(), (9, 7));
            assert_eq!(cur.r.shape(), (7, 60));
            assert_eq!(cur.col_idx.len(), 9);
            assert_eq!(cur.row_idx.len(), 7);
            let res = cur.residual(crate::gmr::Input::Dense(&a));
            assert!(res.is_finite() && res < a.fro_norm(), "router CUR residual {res} not sane");
        }
        _ => panic!("wrong result kind"),
    }
    match h6.wait().unwrap() {
        JobResult::Cur { cur } => {
            assert_eq!(cur.c.shape(), (80, 9));
            assert_eq!(cur.u.shape(), (9, 7));
            assert_eq!(cur.r.shape(), (7, 60));
            let res = cur.residual(crate::gmr::Input::Dense(&a));
            assert!(res.is_finite() && res < a.fro_norm(), "streaming CUR residual {res} not sane");
        }
        _ => panic!("wrong result kind"),
    }
    assert_eq!(router.metrics.get("router.gmr.completed"), 1);
    assert_eq!(router.metrics.get("router.spsd.completed"), 1);
    assert_eq!(router.metrics.get("router.svd.completed"), 1);
    assert_eq!(router.metrics.get("router.cur.completed"), 1);
    assert_eq!(router.metrics.get("router.cur_stream.completed"), 1);
    router.shutdown();
}

/// [`ApproxJob::KINDS`] is the list the router pre-builds its per-kind
/// counter handles from — a variant missing from it would panic
/// executor-side on first dispatch, so pin it against the enum here.
#[test]
fn approx_job_kinds_list_is_exhaustive() {
    let a = test_matrix(10, 8, 1);
    let mut r = rng(2);
    let c = Mat::randn(10, 3, &mut r);
    let rr = Mat::randn(2, 8, &mut r);
    let jobs = [
        ApproxJob::Gmr {
            a: MatrixPayload::Dense(a.clone()),
            c: c.clone(),
            r: rr.clone(),
            cfg: crate::gmr::FastGmrConfig::gaussian(6, 6),
            seed: 0,
        },
        ApproxJob::SpsdKernel { x: Mat::randn(10, 2, &mut r), sigma: 0.4, c: 2, s: 4, seed: 0 },
        ApproxJob::StreamSvd {
            a: MatrixPayload::Dense(a.clone()),
            cfg: FastSpSvdConfig::paper(2, 2, SketchKind::Gaussian),
            block: 4,
            seed: 0,
        },
        ApproxJob::GmrExact { a: MatrixPayload::Dense(a.clone()), c, r: rr },
        ApproxJob::Cur {
            a: MatrixPayload::Dense(a.clone()),
            cfg: crate::cur::CurConfig::fast(3, 3, 2),
            seed: 0,
        },
        ApproxJob::StreamingCur {
            a: MatrixPayload::Dense(a.clone()),
            cfg: crate::cur::StreamingCurConfig::fast(3, 3, 2, 2),
            block: 4,
            seed: 0,
        },
    ];
    let kinds: Vec<&str> = jobs.iter().map(|j| j.kind()).collect();
    assert_eq!(kinds, ApproxJob::KINDS, "ApproxJob::KINDS out of sync with the enum variants");
    for j in &jobs {
        let (rows, cols) = j.dims();
        if j.kind() == "spsd" {
            assert_eq!((rows, cols), (10, 10), "SPSD dims are the implicit n x n kernel");
        } else {
            assert_eq!((rows, cols), (10, 8), "dims must report the payload shape");
        }
        assert!(j.weight() > 0, "{} weight must be positive", j.kind());
    }
}

#[test]
fn router_many_concurrent_jobs() {
    let router = Router::new(3);
    let mut handles = Vec::new();
    for seed in 0..12u64 {
        let a = test_matrix(40, 30, 100 + seed);
        let mut r = rng(seed);
        let g_c = Mat::randn(30, 4, &mut r);
        let c = crate::linalg::matmul(&a, &g_c);
        let g_r = Mat::randn(3, 40, &mut r);
        let rr = crate::linalg::matmul(&g_r, &a);
        let h = router.submit(ApproxJob::Gmr {
            a: MatrixPayload::Dense(a),
            c,
            r: rr,
            cfg: crate::gmr::FastGmrConfig::gaussian(24, 24),
            seed,
        });
        handles.push(h.unwrap());
    }
    for h in handles {
        assert!(matches!(h.wait().unwrap(), JobResult::Gmr { .. }));
    }
    assert_eq!(router.metrics.get("router.gmr.completed"), 12);
}

#[test]
fn tiled_oracle_matches_plain_and_counts() {
    let mut r = rng(10);
    let x = Mat::randn(50, 5, &mut r);
    let backend = CpuBackend;
    let tiled = TiledKernelOracle::new(&x, 0.5, &backend, 16);
    let plain = RbfOracle::new(&x, 0.5);
    let rows: Vec<usize> = (0..37).collect();
    let cols: Vec<usize> = (5..45).collect();
    let got = tiled.block(&rows, &cols);
    let want = plain.block(&rows, &cols);
    assert_close(&got, &want, 1e-12, "tiled oracle");
    assert_eq!(tiled.entries_requested(), (37 * 40) as u64);
    // ceil(37/16) * ceil(40/16) tiles.
    assert_eq!(tiled.tiles_executed(), 3 * 3);
}

#[test]
fn tiled_oracle_drives_algorithm2() {
    let mut r = rng(11);
    let x = crate::data::synth_clustered(150, 8, 6, 0.4, &mut r);
    let backend = CpuBackend;
    let tiled = TiledKernelOracle::new(&x, 0.5, &backend, 32);
    let sol = crate::spsd::faster_spsd(&tiled, &crate::spsd::FasterSpsdConfig { c: 10, s: 50 }, &mut r);
    assert_eq!(sol.x.shape(), (10, 10));
    assert_eq!(tiled.entries_requested(), (150 * 10 + 50 * 50) as u64);
    // Against the dense oracle the result must agree given the same rng.
    let k = crate::data::rbf_kernel(&x, 0.5);
    let dense = DenseKernelOracle { k: &k };
    let mut r2 = rng(11);
    // Reconstruct the same draw sequence: synth_clustered + faster_spsd
    // consumed from r; replay by re-deriving.
    let _ = crate::data::synth_clustered(150, 8, 6, 0.4, &mut r2);
    let sol2 = crate::spsd::faster_spsd(&dense, &crate::spsd::FasterSpsdConfig { c: 10, s: 50 }, &mut r2);
    assert_close(&sol.x, &sol2.x, 1e-9, "tiled vs dense oracle end-to-end");
}

#[test]
fn payload_helpers() {
    let a = test_matrix(10, 8, 12);
    let p = MatrixPayload::Dense(a);
    assert_eq!(p.rows(), 10);
    assert_eq!(p.cols(), 8);
    assert_eq!(jobs::default_kind_for(&p).name(), "gaussian");
    let sp = MatrixPayload::Sparse(crate::sparse::Csr::from_triplets(4, 4, vec![]));
    assert_eq!(jobs::default_kind_for(&sp).name(), "count");
}

// ---- serving layer: admission, deadlines, cache, batching -----------

use crate::coordinator::router::ServeConfig;
use crate::error::FgError;
use std::time::Duration;

/// A job heavy enough (hundreds-of-ms scale) to occupy a single worker
/// while the test submits fast follow-ups — the timing anchor for the
/// admission/deadline/batching tests.
fn slow_job(seed: u64) -> ApproxJob {
    ApproxJob::StreamSvd {
        a: MatrixPayload::Dense(test_matrix(260, 240, seed)),
        cfg: FastSpSvdConfig::paper(10, 8, SketchKind::Gaussian),
        block: 32,
        seed,
    }
}

fn quick_cur_job(a: &Mat, seed: u64) -> ApproxJob {
    ApproxJob::Cur {
        a: MatrixPayload::Dense(a.clone()),
        cfg: crate::cur::CurConfig::fast(6, 5, 3),
        seed,
    }
}

#[test]
fn submit_sheds_with_overloaded_when_queue_full() {
    let router = Router::with_config(&ServeConfig {
        workers: 1,
        queue_depth: 2,
        ..ServeConfig::service(1)
    });
    let a = test_matrix(50, 40, 60);
    // Occupy the single worker, then overfill the bounded queue.
    let occupier = router.submit(slow_job(61)).unwrap();
    let mut accepted = Vec::new();
    let mut shed = 0;
    for seed in 0..3u64 {
        match router.submit(quick_cur_job(&a, seed)) {
            Ok(h) => accepted.push(h),
            Err(FgError::Overloaded { depth }) => {
                assert_eq!(depth, 2, "shed error must report the configured bound");
                shed += 1;
            }
            Err(e) => panic!("unexpected error kind: {e}"),
        }
    }
    assert!(shed >= 1, "a 3rd submit against a depth-2 queue must shed");
    assert_eq!(router.metrics.get("serve.shed"), shed);
    // Shedding must not corrupt the queue: everything accepted completes.
    assert!(matches!(occupier.wait().unwrap(), JobResult::Svd { .. }));
    let accepted_n = accepted.len() as u64;
    for h in accepted {
        assert!(matches!(h.wait().unwrap(), JobResult::Cur { .. }));
    }
    assert_eq!(router.metrics.get("router.cur.completed"), accepted_n);
    assert!(router.metrics.get("serve.queue.peak") <= 2);
}

#[test]
fn deadline_expired_jobs_fail_cleanly() {
    let router = Router::with_config(&ServeConfig::service(1));
    let a = test_matrix(50, 40, 62);
    let occupier = router.submit(slow_job(63)).unwrap();
    // Expires in the queue while the occupier holds the worker.
    let doomed = router.submit_with_deadline(quick_cur_job(&a, 0), Some(Duration::from_millis(1)));
    let alive = router.submit(quick_cur_job(&a, 1)).unwrap();
    match doomed.unwrap().wait() {
        Err(FgError::DeadlineExceeded { waited_ms }) => {
            assert!(waited_ms >= 1, "expired job must report its queue wait");
        }
        Err(e) => panic!("expected DeadlineExceeded, got error: {e}"),
        Ok(_) => panic!("expected DeadlineExceeded, got a result"),
    }
    // The executor survives: jobs behind the expired one still complete.
    assert!(matches!(alive.wait().unwrap(), JobResult::Cur { .. }));
    assert!(matches!(occupier.wait().unwrap(), JobResult::Svd { .. }));
    assert_eq!(router.metrics.get("serve.deadline_expired"), 1);
    assert_eq!(router.metrics.get("router.cur.completed"), 1, "expired jobs never execute");

    // Caller-side timeout: waiting stops, the job itself still runs.
    let slow = router.submit(slow_job(64)).unwrap();
    match slow.wait_timeout(Duration::from_millis(1)) {
        Err(FgError::DeadlineExceeded { waited_ms }) => assert_eq!(waited_ms, 1),
        Err(e) => panic!("expected wait_timeout to expire, got error: {e}"),
        Ok(_) => panic!("expected wait_timeout to expire, got a result"),
    }
    router.shutdown();
}

#[test]
fn panicking_job_does_not_poison_the_executor() {
    let router = Router::with_config(&ServeConfig::service(1));
    let a = test_matrix(40, 30, 65);
    // C has the wrong row count: solve_exact asserts, the job panics.
    let bad = ApproxJob::GmrExact {
        a: MatrixPayload::Dense(a.clone()),
        c: Mat::zeros(12, 4),
        r: Mat::zeros(3, 30),
    };
    let h_bad = router.submit(bad).unwrap();
    match h_bad.wait() {
        Err(FgError::Runtime(msg)) => {
            assert!(msg.contains("panicked"), "panic must surface as a Runtime error: {msg}")
        }
        Err(e) => panic!("expected a Runtime error from the panicking job, got: {e}"),
        Ok(_) => panic!("expected a Runtime error from the panicking job, got a result"),
    }
    // Same worker thread keeps serving.
    let h_ok = router.submit(quick_cur_job(&a, 2)).unwrap();
    assert!(matches!(h_ok.wait().unwrap(), JobResult::Cur { .. }));
    assert_eq!(router.metrics.get("router.gmr_exact.completed"), 1);
    assert_eq!(router.metrics.get("router.cur.completed"), 1);
}

#[test]
fn cache_hit_returns_bitwise_identical_result() {
    let router = Router::with_config(&ServeConfig {
        workers: 2,
        cache_bytes: 64 << 20,
        ..ServeConfig::service(2)
    });
    let a = test_matrix(80, 60, 66);
    let job = |seed| ApproxJob::Cur {
        a: MatrixPayload::Dense(a.clone()),
        cfg: crate::cur::CurConfig::fast(8, 6, 3),
        seed,
    };
    let JobResult::Cur { cur: cold } = router.submit(job(5)).unwrap().wait().unwrap() else {
        panic!("wrong result kind")
    };
    let JobResult::Cur { cur: warm } = router.submit(job(5)).unwrap().wait().unwrap() else {
        panic!("wrong result kind")
    };
    assert_eq!(router.metrics.get("serve.cache.hits"), 1);
    assert_eq!(router.metrics.get("serve.cache.misses"), 1);
    assert_eq!(router.metrics.get("router.cur.completed"), 1, "the hit must not execute");
    // The serving contract: a hit is a clone of the stored artifact, so
    // it is *bitwise* identical to the cold compute.
    assert_eq!(cold.col_idx, warm.col_idx);
    assert_eq!(cold.row_idx, warm.row_idx);
    assert_eq!(cold.c.data(), warm.c.data());
    assert_eq!(cold.u.data(), warm.u.data());
    assert_eq!(cold.r.data(), warm.r.data());
    // A different seed is a different key: miss, not a stale hit.
    assert!(matches!(router.submit(job(6)).unwrap().wait().unwrap(), JobResult::Cur { .. }));
    assert_eq!(router.metrics.get("serve.cache.hits"), 1);
    assert_eq!(router.metrics.get("serve.cache.misses"), 2);
    assert_eq!(router.metrics.get("serve.cache.entries"), 2);
    let manifest = router.cache_manifest().expect("cache enabled");
    assert!(manifest.contains("2 entries"), "{manifest}");
    assert!(manifest.contains("cur_"), "{manifest}");
}

#[test]
fn batch_window_coalesces_identical_inflight_jobs() {
    let router = Router::with_config(&ServeConfig {
        workers: 1,
        batch_window: Duration::from_secs(5),
        ..ServeConfig::service(1)
    });
    let a = test_matrix(70, 50, 67);
    // The occupier pins the single worker, so the leader below stays
    // in-flight (queued) while the two followers coalesce onto it.
    let occupier = router.submit(slow_job(68)).unwrap();
    let leader = router.submit(quick_cur_job(&a, 9)).unwrap();
    let follower1 = router.submit(quick_cur_job(&a, 9)).unwrap();
    let follower2 = router.submit(quick_cur_job(&a, 9)).unwrap();
    assert_eq!(router.metrics.get("serve.batch.coalesced"), 2);
    assert!(matches!(occupier.wait().unwrap(), JobResult::Svd { .. }));
    let JobResult::Cur { cur: lead } = leader.wait().unwrap() else { panic!("wrong kind") };
    let JobResult::Cur { cur: f1 } = follower1.wait().unwrap() else { panic!("wrong kind") };
    let JobResult::Cur { cur: f2 } = follower2.wait().unwrap() else { panic!("wrong kind") };
    // One execution fanned out to all three waiters, bitwise.
    assert_eq!(router.metrics.get("router.cur.completed"), 1);
    for got in [&f1, &f2] {
        assert_eq!(lead.col_idx, got.col_idx);
        assert_eq!(lead.c.data(), got.c.data());
        assert_eq!(lead.u.data(), got.u.data());
        assert_eq!(lead.r.data(), got.r.data());
    }
}

// ---- robustness: fault injection, retries, breakers, degradation,
// ---- warm start ------------------------------------------------------

use crate::faults::{site, FaultPlan, RetryPolicy};
use std::sync::Arc;

/// A GmrExact job whose C payload has the wrong row count, so the
/// executor's solver asserts and the job panics deterministically.
fn panicking_job(a: &Mat) -> ApproxJob {
    ApproxJob::GmrExact {
        a: MatrixPayload::Dense(a.clone()),
        c: Mat::zeros(12, 4),
        r: Mat::zeros(3, 30),
    }
}

/// An injected executor panic is healed by job-level retry: the fault
/// plan panics the first `cur` execution, the retry re-runs it clean,
/// and the caller sees a normal result.
#[test]
fn injected_executor_panic_is_healed_by_retry() {
    let plan = Arc::new(FaultPlan::new(0xC4A05).with_site(site::executor("cur"), 1.0, 1));
    let router = Router::with_config(&ServeConfig {
        workers: 1,
        retry: RetryPolicy {
            max_attempts: 3,
            base_backoff: Duration::from_millis(1),
            cap: Duration::from_millis(5),
        },
        faults: Some(plan.clone()),
        ..ServeConfig::service(1)
    });
    let a = test_matrix(50, 40, 70);
    let h = router.submit(quick_cur_job(&a, 3)).unwrap();
    assert!(matches!(h.wait().unwrap(), JobResult::Cur { .. }), "retry must heal the panic");
    assert_eq!(plan.injected_at("executor.cur"), 1, "the plan must have actually injected");
    assert_eq!(router.metrics.get("serve.retries"), 1, "exactly one job-level retry");
    assert_eq!(router.metrics.get("faults.injected"), 1, "gauge mirrors the plan total");
    assert_eq!(router.metrics.get("router.cur.completed"), 1);
}

/// Breaker lifecycle: `threshold` consecutive post-retry panics open the
/// kind's breaker (later submits fail fast with [`FgError::CircuitOpen`]
/// and never execute), the cooldown admits a half-open probe, and a
/// probe success closes it again. Other kinds are unaffected throughout.
#[test]
fn circuit_breaker_opens_fails_fast_and_recovers() {
    let router = Router::with_config(&ServeConfig {
        workers: 1,
        breaker_threshold: 2,
        breaker_cooldown: Duration::from_millis(50),
        ..ServeConfig::service(1)
    });
    let a = test_matrix(40, 30, 71);
    for _ in 0..2 {
        match router.submit(panicking_job(&a)).unwrap().wait() {
            Err(FgError::Runtime(msg)) => {
                assert!(msg.contains("panicked in executor"), "unexpected message: {msg}")
            }
            Err(e) => panic!("expected a Runtime panic error, got: {e}"),
            Ok(_) => panic!("expected a Runtime panic error, got a result"),
        }
    }
    assert_eq!(router.metrics.get("serve.breaker_open"), 1, "threshold-th failure opens");
    // Open: fail fast, no execution. A *good* job is rejected too — the
    // breaker is per kind, not per payload.
    let mut rg = rng(78);
    let gc = Mat::randn(40, 4, &mut rg);
    let gr = Mat::randn(3, 30, &mut rg);
    let good = || ApproxJob::GmrExact {
        a: MatrixPayload::Dense(a.clone()),
        c: gc.clone(),
        r: gr.clone(),
    };
    match router.submit(good()).unwrap().wait() {
        Err(FgError::CircuitOpen { kind }) => assert_eq!(kind, "gmr_exact"),
        Err(e) => panic!("expected CircuitOpen while open, got: {e}"),
        Ok(_) => panic!("expected CircuitOpen while open, got a result"),
    }
    assert_eq!(
        router.metrics.get("router.gmr_exact.completed"),
        3,
        "completed counts the fast-fail dispatch but nothing executed past the breaker"
    );
    // Other kinds keep flowing while gmr_exact is open.
    assert!(matches!(
        router.submit(quick_cur_job(&a, 4)).unwrap().wait().unwrap(),
        JobResult::Cur { .. }
    ));
    // Cooldown elapses: the half-open probe executes, succeeds, closes.
    std::thread::sleep(Duration::from_millis(60));
    match router.submit(good()).unwrap().wait() {
        Ok(JobResult::Gmr { x }) => assert_eq!(x.shape(), (4, 3)),
        Err(e) => panic!("expected the half-open probe to succeed, got: {e}"),
        Ok(_) => panic!("expected the half-open probe to return a GMR solve"),
    }
    // Closed again: the next job runs normally.
    assert!(matches!(router.submit(good()).unwrap().wait().unwrap(), JobResult::Gmr { .. }));
}

/// Graceful degradation: admission pressure (an injected `queue.admission`
/// fault) re-plans the job at a smaller sketch tier instead of shedding.
/// The result is tagged [`JobResult::Degraded`] with a finite verified
/// residual estimate, and is *not* cached — the next uncontended request
/// recomputes at full fidelity and only then populates the cache.
#[test]
fn degraded_admission_verifies_and_never_caches() {
    let plan = Arc::new(FaultPlan::new(0xDE64).with_site(site::QUEUE_ADMISSION, 1.0, 1));
    let router = Router::with_config(&ServeConfig {
        workers: 1,
        cache_bytes: 64 << 20,
        degrade: true,
        faults: Some(plan),
        ..ServeConfig::service(1)
    });
    let a = test_matrix(80, 60, 72);
    let job = || quick_cur_job(&a, 5);
    match router.submit(job()).unwrap().wait().unwrap() {
        JobResult::Degraded { est_rel_residual, inner } => {
            assert!(matches!(*inner, JobResult::Cur { .. }), "inner must be the real result");
            assert!(
                est_rel_residual.is_finite() && est_rel_residual >= 0.0,
                "degraded CUR must carry a verified residual, got {est_rel_residual}"
            );
        }
        _ => panic!("expected a Degraded result under admission pressure"),
    }
    assert_eq!(router.metrics.get("serve.degraded"), 1);
    assert_eq!(router.metrics.get("serve.shed"), 0, "degradation replaces shedding");
    // The degraded artifact was not cached: the same request misses and
    // recomputes at full fidelity.
    match router.submit(job()).unwrap().wait().unwrap() {
        JobResult::Cur { .. } => {}
        _ => panic!("uncontended recompute must be full fidelity, not degraded"),
    }
    assert_eq!(router.metrics.get("serve.cache.hits"), 0);
    assert_eq!(router.metrics.get("serve.cache.misses"), 2);
    assert_eq!(router.metrics.get("router.cur.completed"), 2);
    // Third time is the cached full-fidelity artifact.
    assert!(matches!(router.submit(job()).unwrap().wait().unwrap(), JobResult::Cur { .. }));
    assert_eq!(router.metrics.get("serve.cache.hits"), 1);
}

/// Accuracy SLO: with `ServeConfig.epsilon` set every planner-capable
/// job routes through the ε-planned solver — the `serve.plan.*`
/// counters record the attempts, and the served artifact is bitwise the
/// direct `decompose_planned` call with the job's seed (the SLO changes
/// sizing, never the algorithm).
#[test]
fn epsilon_slo_routes_jobs_through_the_planner() {
    let eps = 0.25;
    let router = Router::with_config(&ServeConfig {
        workers: 1,
        epsilon: Some(eps),
        ..ServeConfig::service(1)
    });
    let a = test_matrix(80, 60, 75);
    let JobResult::Cur { cur } = router.submit(quick_cur_job(&a, 5)).unwrap().wait().unwrap()
    else {
        panic!("expected a CUR result")
    };
    let attempts = router.metrics.get("serve.plan.attempts");
    assert!(attempts >= 1, "SLO jobs must run the planner (attempts {attempts})");
    assert_eq!(
        router.metrics.get("serve.plan.escalations"),
        attempts - 1,
        "escalations are attempts beyond the first"
    );
    assert_eq!(router.metrics.get("serve.plan.misses"), 0, "saturated check cannot miss");

    let plan = crate::plan::EpsilonPlan::new(eps).with_seed(5);
    let mut rr = rng(5);
    let (direct, outcome) =
        crate::cur::decompose_planned(
            crate::gmr::Input::Dense(&a),
            &crate::cur::CurConfig::fast(6, 5, 3),
            &plan,
            &mut rr,
        );
    assert!(outcome.attained, "planner must certify at this scale: {outcome:?}");
    assert_eq!(outcome.attempts as u64, attempts, "served attempt count drifted from direct");
    assert_eq!(cur.col_idx, direct.col_idx, "served selection drifted from direct planned run");
    assert_eq!(cur.u.data(), direct.u.data(), "served core not bitwise vs direct planned run");
}

/// Degraded-tier jobs deliberately skip the ε-planner: degradation
/// trades accuracy for admission, and re-planning would escalate right
/// back up. The job still reports its estimated residual through the
/// `Degraded` tag instead of silently violating the SLO.
#[test]
fn degraded_jobs_bypass_the_epsilon_planner() {
    let plan = Arc::new(FaultPlan::new(0xDE66).with_site(site::QUEUE_ADMISSION, 1.0, 1));
    let router = Router::with_config(&ServeConfig {
        workers: 1,
        degrade: true,
        epsilon: Some(0.25),
        faults: Some(plan),
        ..ServeConfig::service(1)
    });
    let a = test_matrix(80, 60, 76);
    match router.submit(quick_cur_job(&a, 6)).unwrap().wait().unwrap() {
        JobResult::Degraded { est_rel_residual, inner } => {
            assert!(matches!(*inner, JobResult::Cur { .. }));
            assert!(est_rel_residual.is_finite() && est_rel_residual >= 0.0);
        }
        _ => panic!("expected a Degraded result under admission pressure"),
    }
    assert_eq!(router.metrics.get("serve.degraded"), 1);
    assert_eq!(
        router.metrics.get("serve.plan.attempts"),
        0,
        "degraded jobs must not run the planner"
    );
    // The next uncontended request is full fidelity again — and planned.
    assert!(matches!(router.submit(quick_cur_job(&a, 6)).unwrap().wait().unwrap(), JobResult::Cur { .. }));
    assert!(router.metrics.get("serve.plan.attempts") >= 1, "full-fidelity jobs honour the SLO");
}

/// A shed still happens when degradation is on but the job *cannot*
/// degrade (the exact baseline has no accuracy knob).
#[test]
fn undegradable_jobs_are_still_shed_under_pressure() {
    let plan = Arc::new(FaultPlan::new(0xDE65).with_site(site::QUEUE_ADMISSION, 1.0, 1));
    let router = Router::with_config(&ServeConfig {
        workers: 1,
        degrade: true,
        faults: Some(plan),
        ..ServeConfig::service(1)
    });
    let a = test_matrix(40, 30, 73);
    let good = ApproxJob::GmrExact {
        a: MatrixPayload::Dense(a.clone()),
        c: Mat::zeros(40, 4),
        r: Mat::zeros(3, 30),
    };
    match router.submit(good) {
        Err(FgError::Overloaded { .. }) => {}
        Err(e) => panic!("expected the exact job to shed with Overloaded, got: {e}"),
        Ok(_) => panic!("expected the exact job to shed, but it was admitted"),
    }
    assert_eq!(router.metrics.get("serve.shed"), 1);
    assert_eq!(router.metrics.get("serve.degraded"), 0);
}

/// Crash-safe warm start end-to-end: a router persists its artifact
/// cache on drop; a restarted router warm-starts from the file and
/// serves *bitwise-identical* cache hits without executing; a router
/// whose persist "crashes" (injected `cache.persist` fault) leaves the
/// previous inventory intact. A stale `.tmp` from a torn write is
/// ignored throughout.
#[test]
fn warm_start_survives_restart_with_bitwise_hits() {
    let path = std::path::PathBuf::from("/tmp/fastgmr_router_warm_start_test.txt");
    let tmp = path.with_extension("tmp");
    let _ = std::fs::remove_file(&path);
    let serve = |faults: Option<Arc<FaultPlan>>| ServeConfig {
        workers: 1,
        cache_bytes: 64 << 20,
        cache_path: Some(path.clone()),
        faults,
        ..ServeConfig::service(1)
    };
    let a = test_matrix(80, 60, 74);
    let job = |seed| ApproxJob::Cur {
        a: MatrixPayload::Dense(a.clone()),
        cfg: crate::cur::CurConfig::fast(8, 6, 3),
        seed,
    };

    // Generation 1: compute cold, persist on drop.
    let r1 = Router::with_config(&serve(None));
    let JobResult::Cur { cur: cold } = r1.submit(job(5)).unwrap().wait().unwrap() else {
        panic!("wrong result kind")
    };
    drop(r1);
    assert!(path.exists(), "drop must persist the cache inventory");
    assert!(!tmp.exists(), "the temp file must be renamed away");

    // A torn write from a crashed persist must not confuse the restart.
    std::fs::write(&tmp, "garbage from a torn write").unwrap();

    // Generation 2: warm-start, serve the hit without executing.
    let r2 = Router::with_config(&serve(None));
    assert_eq!(r2.metrics.get("serve.warm_start.loaded"), 1);
    assert_eq!(r2.metrics.get("serve.warm_start.skipped_corrupt"), 0);
    let JobResult::Cur { cur: warm } = r2.submit(job(5)).unwrap().wait().unwrap() else {
        panic!("wrong result kind")
    };
    assert_eq!(r2.metrics.get("serve.cache.hits"), 1);
    assert_eq!(r2.metrics.get("router.cur.completed"), 0, "a warm hit never executes");
    assert_eq!(cold.col_idx, warm.col_idx);
    assert_eq!(cold.row_idx, warm.row_idx);
    assert_eq!(cold.c.data(), warm.c.data());
    assert_eq!(cold.u.data(), warm.u.data());
    assert_eq!(cold.r.data(), warm.r.data());
    drop(r2);

    // Generation 3: compute a second artifact but crash during persist —
    // the inventory on disk keeps generation 2's content.
    let crash = Arc::new(FaultPlan::new(0xC4A54).with_site(site::CACHE_PERSIST, 1.0, 1));
    let before = std::fs::read_to_string(&path).unwrap();
    let r3 = Router::with_config(&serve(Some(crash)));
    assert!(matches!(r3.submit(job(6)).unwrap().wait().unwrap(), JobResult::Cur { .. }));
    drop(r3);
    let after = std::fs::read_to_string(&path).unwrap();
    assert_eq!(before, after, "a crashed persist must leave the old inventory intact");

    // Generation 4: the survivor still warm-starts job 5, recomputes 6.
    let r4 = Router::with_config(&serve(None));
    assert_eq!(r4.metrics.get("serve.warm_start.loaded"), 1);
    assert!(matches!(r4.submit(job(6)).unwrap().wait().unwrap(), JobResult::Cur { .. }));
    assert_eq!(r4.metrics.get("serve.cache.misses"), 1, "job 6 was lost with the crash");
    drop(r4);
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&tmp);
}

/// An injected `cache.warm_start` fault degrades construction to a cold
/// start instead of failing it: availability over the cache.
#[test]
fn injected_warm_start_fault_degrades_to_cold_start() {
    let path = std::path::PathBuf::from("/tmp/fastgmr_router_warm_start_fault_test.txt");
    let _ = std::fs::remove_file(&path);
    let a = test_matrix(50, 40, 75);
    let serve = |faults: Option<Arc<FaultPlan>>| ServeConfig {
        workers: 1,
        cache_bytes: 64 << 20,
        cache_path: Some(path.clone()),
        faults,
        ..ServeConfig::service(1)
    };
    let r1 = Router::with_config(&serve(None));
    let h = r1.submit(quick_cur_job(&a, 7)).unwrap();
    assert!(matches!(h.wait().unwrap(), JobResult::Cur { .. }));
    drop(r1);
    let plan = Arc::new(FaultPlan::new(0x401D).with_site(site::CACHE_WARM_START, 1.0, 1));
    let r2 = Router::with_config(&serve(Some(plan.clone())));
    assert_eq!(plan.injected_at(site::CACHE_WARM_START), 1);
    assert_eq!(r2.metrics.get("serve.warm_start.loaded"), 0, "faulted warm start is cold");
    // Cold but alive: the job recomputes.
    let h = r2.submit(quick_cur_job(&a, 7)).unwrap();
    assert!(matches!(h.wait().unwrap(), JobResult::Cur { .. }));
    assert_eq!(r2.metrics.get("serve.cache.misses"), 1);
    drop(r2);
    let _ = std::fs::remove_file(&path);
}

/// Coalesced followers must observe the lead's *error* exactly as the
/// lead does: a panicking lead fans its Runtime error out to every
/// follower in the batch window.
#[test]
fn coalesced_followers_observe_the_leads_error() {
    let router = Router::with_config(&ServeConfig {
        workers: 1,
        batch_window: Duration::from_secs(5),
        ..ServeConfig::service(1)
    });
    let a = test_matrix(40, 30, 76);
    // Pin the single worker so the panicking lead stays in-flight while
    // the follower coalesces onto it.
    let occupier = router.submit(slow_job(77)).unwrap();
    let lead = router.submit(panicking_job(&a)).unwrap();
    let follower = router.submit(panicking_job(&a)).unwrap();
    assert_eq!(router.metrics.get("serve.batch.coalesced"), 1);
    assert!(matches!(occupier.wait().unwrap(), JobResult::Svd { .. }));
    let mut msgs = Vec::new();
    for h in [lead, follower] {
        match h.wait() {
            Err(FgError::Runtime(msg)) => {
                assert!(msg.contains("panicked in executor"), "unexpected variant: {msg}");
                msgs.push(msg);
            }
            Err(e) => panic!("every waiter must see the Runtime panic error, got: {e}"),
            Ok(_) => panic!("every waiter must see the Runtime panic error, got a result"),
        }
    }
    assert_eq!(msgs[0], msgs[1], "follower must observe the lead's exact error");
    assert_eq!(router.metrics.get("router.gmr_exact.completed"), 1, "one execution, two errors");
}

/// Cache TTL through the serving path: with `cache_ttl` set, a resident
/// artifact older than the TTL (in logical cache ticks) is recomputed —
/// counted both as `serve.cache.expired` and as a miss — while a
/// generous TTL still serves hits.
#[test]
fn cache_ttl_expires_through_the_router() {
    let serve = |ttl| ServeConfig {
        workers: 1,
        cache_bytes: 64 << 20,
        cache_ttl: ttl,
        ..ServeConfig::service(1)
    };
    let a = test_matrix(40, 30, 81);
    let b = test_matrix(40, 30, 82);

    // ttl=1: A inserts at tick 2; B's lookup+insert burn ticks 3-4; A's
    // re-lookup at tick 5 sees age 3 > 1 → expired, recomputed.
    let tight = Router::with_config(&serve(1));
    tight.submit(quick_cur_job(&a, 1)).unwrap().wait().unwrap();
    tight.submit(quick_cur_job(&b, 2)).unwrap().wait().unwrap();
    tight.submit(quick_cur_job(&a, 1)).unwrap().wait().unwrap();
    assert_eq!(tight.metrics.get("serve.cache.expired"), 1);
    assert_eq!(tight.metrics.get("serve.cache.misses"), 3);
    assert_eq!(tight.metrics.get("serve.cache.hits"), 0);

    // The same sequence under a generous TTL is a plain hit.
    let loose = Router::with_config(&serve(100));
    loose.submit(quick_cur_job(&a, 1)).unwrap().wait().unwrap();
    loose.submit(quick_cur_job(&b, 2)).unwrap().wait().unwrap();
    loose.submit(quick_cur_job(&a, 1)).unwrap().wait().unwrap();
    assert_eq!(loose.metrics.get("serve.cache.expired"), 0);
    assert_eq!(loose.metrics.get("serve.cache.hits"), 1);
}

/// Shutdown ordering: `Router::drain` must persist the cache and flush
/// the configured trace/metrics exports *before it returns* — not defer
/// them to `Drop` — and the finalization must run exactly once.
#[test]
fn drain_persists_and_flushes_exports_before_returning() {
    let dir = std::path::PathBuf::from(format!(
        "/tmp/fastgmr_drain_exports_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let cache_path = dir.join("inventory.txt");
    let trace_path = dir.join("trace.json");
    let metrics_path = dir.join("metrics.prom");

    let router = Router::with_config(&ServeConfig {
        workers: 1,
        cache_bytes: 64 << 20,
        cache_path: Some(cache_path.clone()),
        trace: Some(Arc::new(crate::obs::TraceCollector::new())),
        trace_path: Some(trace_path.clone()),
        metrics_path: Some(metrics_path.clone()),
        ..ServeConfig::service(1)
    });
    let a = test_matrix(40, 30, 83);
    router.submit(quick_cur_job(&a, 9)).unwrap().wait().unwrap();

    // By shared reference — the router is still alive afterwards.
    router.drain();
    assert!(cache_path.exists(), "drain must persist the cache before returning");
    assert!(trace_path.exists(), "drain must flush the trace export before returning");
    assert!(metrics_path.exists(), "drain must flush the metrics export before returning");
    let prom = std::fs::read_to_string(&metrics_path).unwrap();
    assert!(prom.contains("serve_cache_misses"), "metrics export must be prometheus text");
    let trace = std::fs::read_to_string(&trace_path).unwrap();
    assert!(trace.contains("router.dispatch"), "trace export must hold the dispatch spans");

    // A drained router refuses new work with a typed error...
    let err = router.submit(quick_cur_job(&a, 10)).unwrap_err();
    assert!(matches!(&err, FgError::Coordinator(m) if m.contains("shut down")), "got {err}");

    // ...and Drop must not re-run the finalization (once-guard): delete
    // the outputs, drop the router, nothing reappears.
    std::fs::remove_file(&cache_path).unwrap();
    std::fs::remove_file(&trace_path).unwrap();
    std::fs::remove_file(&metrics_path).unwrap();
    drop(router);
    assert!(!cache_path.exists(), "Drop after drain must not persist again");
    assert!(!trace_path.exists(), "Drop after drain must not flush traces again");
    assert!(!metrics_path.exists(), "Drop after drain must not flush metrics again");
    let _ = std::fs::remove_dir_all(&dir);
}
