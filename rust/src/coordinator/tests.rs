//! Coordinator tests: pipeline invariants, router behaviour, batcher
//! accounting.

use super::*;
use crate::compute::CpuBackend;
use crate::coordinator::jobs::MatrixPayload;
use crate::linalg::Mat;
use crate::rng::rng;
use crate::sketch::SketchKind;
use crate::spsd::{DenseKernelOracle, KernelOracle, RbfOracle};
use crate::svdstream::fast::{fast_sp_svd_with, FastSpSvdSketches};
use crate::svdstream::source::DenseColumnStream;
use crate::svdstream::FastSpSvdConfig;
use crate::testing::assert_close;

fn test_matrix(m: usize, n: usize, seed: u64) -> Mat {
    let mut r = rng(seed);
    crate::data::synth_dense(m, n, 20, crate::data::SpectrumKind::Exponential { base: 0.8 }, 0.05, &mut r)
}

/// The concurrent pipeline must produce exactly the single-threaded
/// reference result given the same sketches (all updates commute).
#[test]
fn pipeline_matches_reference() {
    let a = test_matrix(120, 100, 1);
    let cfg = FastSpSvdConfig::paper(5, 4, SketchKind::Gaussian);
    let mut r = rng(2);
    let sketches = FastSpSvdSketches::draw(&cfg, 120, 100, &mut r);

    let mut ref_stream = DenseColumnStream::new(&a, 16);
    let reference = fast_sp_svd_with(&mut ref_stream, &cfg, &sketches);

    for workers in [1usize, 3] {
        let pipeline = StreamPipeline::new(PipelineConfig { workers, queue_depth: 2 });
        // OnePassStream panics on any replay: the SVD pipeline must be
        // single-pass just like the CUR one.
        let mut stream = crate::svdstream::OnePassStream::new(DenseColumnStream::new(&a, 16));
        let result = pipeline.run(&mut stream, &cfg, &sketches).unwrap();
        assert_eq!(result.blocks, stream.blocks());
        assert_close(&result.u, &reference.u, 1e-8, &format!("U ({workers} workers)"));
        assert_close(&result.v, &reference.v, 1e-8, &format!("V ({workers} workers)"));
        for (a_, b_) in result.sigma.iter().zip(&reference.sigma) {
            assert!((a_ - b_).abs() < 1e-8);
        }
        assert_eq!(result.blocks, reference.blocks);
    }
}

/// The concurrent streaming-CUR pipeline must be *bitwise* identical to
/// the single-threaded reference for every worker count: the fold is
/// driver-side in stream order and the Gaussian applies are bitwise, so
/// nothing may drift — indices, retained columns, core, resolved rows.
#[test]
fn pipeline_cur_matches_reference_bitwise() {
    let a = test_matrix(150, 180, 20);
    let cfg = crate::cur::StreamingCurConfig::fast(12, 12, 8, 3);
    let mut rs = rng(31);
    let sketches = crate::cur::StreamingCurSketches::draw(&cfg, 150, 180, &mut rs);

    let mut ref_stream = DenseColumnStream::new(&a, 48);
    let mut r1 = rng(32);
    let reference = crate::cur::streaming_cur_with(&mut ref_stream, &cfg, &sketches, &mut r1);

    for workers in [1usize, 3] {
        let pipeline = StreamPipeline::new(PipelineConfig { workers, queue_depth: 4 });
        let mut stream = crate::svdstream::OnePassStream::new(DenseColumnStream::new(&a, 48));
        let mut r2 = rng(32);
        let result = pipeline.run_cur(&mut stream, &cfg, &sketches, &mut r2).unwrap();
        assert_eq!(result.blocks, reference.blocks);
        assert_eq!(result.blocks, stream.blocks());
        assert_eq!(result.candidates, reference.candidates);
        assert_eq!(
            result.cur.col_idx,
            reference.cur.col_idx,
            "column selection drifted at {workers} workers"
        );
        assert_eq!(
            result.cur.row_idx,
            reference.cur.row_idx,
            "row selection drifted at {workers} workers"
        );
        assert_eq!(result.cur.c.data(), reference.cur.c.data());
        assert_eq!(result.cur.u.data(), reference.cur.u.data());
        assert_eq!(result.cur.r.data(), reference.cur.r.data());
        assert_eq!(pipeline.metrics.get("pipeline.cur_blocks"), reference.blocks as u64);
        assert_eq!(pipeline.metrics.get("pipeline.cur_cols"), 180);
        assert_eq!(
            pipeline.metrics.get("pipeline.cur_reservoir_candidates"),
            reference.candidates as u64
        );
    }
}

/// Every block is processed exactly once and backpressure bounds the
/// in-flight queue depth.
#[test]
fn pipeline_processes_each_block_once_with_bounded_queue() {
    let a = test_matrix(60, 90, 3);
    let cfg = FastSpSvdConfig::paper(4, 3, SketchKind::Gaussian);
    let mut r = rng(4);
    let sketches = FastSpSvdSketches::draw(&cfg, 60, 90, &mut r);
    let depth = 3;
    let pipeline = StreamPipeline::new(PipelineConfig { workers: 2, queue_depth: depth });
    let mut stream = DenseColumnStream::new(&a, 8);
    let result = pipeline.run(&mut stream, &cfg, &sketches).unwrap();
    let expected_blocks = (90 + 7) / 8;
    assert_eq!(result.blocks, expected_blocks);
    assert_eq!(pipeline.metrics.get("pipeline.blocks"), expected_blocks as u64);
    assert_eq!(pipeline.metrics.get("pipeline.blocks_sent"), expected_blocks as u64);
    assert_eq!(pipeline.metrics.get("pipeline.cols"), 90);
    // Batch design: at most `workers` blocks are ever in flight (the
    // metric records the largest batch), tighter than the old channel's
    // `depth + workers` bound.
    assert!(
        pipeline.max_queue_depth() <= 2,
        "in-flight blocks {} exceeded the `workers` bound",
        pipeline.max_queue_depth()
    );
}

#[test]
fn router_executes_all_job_kinds() {
    let router = Router::new(2);
    let a = test_matrix(80, 60, 5);
    let mut r = rng(6);
    let g_c = Mat::randn(60, 6, &mut r);
    let c = crate::linalg::matmul(&a, &g_c);
    let g_r = Mat::randn(5, 80, &mut r);
    let rr = crate::linalg::matmul(&g_r, &a);

    let h1 = router
        .submit(ApproxJob::Gmr {
            a: MatrixPayload::Dense(a.clone()),
            c: c.clone(),
            r: rr.clone(),
            cfg: crate::gmr::FastGmrConfig::gaussian(48, 40),
            seed: 7,
        })
        .unwrap();
    let h2 = router
        .submit(ApproxJob::GmrExact {
            a: MatrixPayload::Dense(a.clone()),
            c: c.clone(),
            r: rr.clone(),
        })
        .unwrap();
    let x_pts = Mat::randn(100, 6, &mut r);
    let h3 = router
        .submit(ApproxJob::SpsdKernel { x: x_pts, sigma: 0.4, c: 8, s: 40, seed: 8 })
        .unwrap();
    let h4 = router
        .submit(ApproxJob::StreamSvd {
            a: MatrixPayload::Dense(a.clone()),
            cfg: FastSpSvdConfig::paper(4, 3, SketchKind::Gaussian),
            block: 16,
            seed: 9,
        })
        .unwrap();
    let h5 = router
        .submit(ApproxJob::Cur {
            a: MatrixPayload::Dense(a.clone()),
            cfg: crate::cur::CurConfig::fast(9, 7, 3),
            seed: 10,
        })
        .unwrap();
    let h6 = router
        .submit(ApproxJob::StreamingCur {
            a: MatrixPayload::Dense(a.clone()),
            cfg: crate::cur::StreamingCurConfig::fast(9, 7, 4, 3),
            block: 16,
            seed: 11,
        })
        .unwrap();

    match h1.wait().unwrap() {
        JobResult::Gmr { x } => assert_eq!(x.shape(), (6, 5)),
        _ => panic!("wrong result kind"),
    }
    match h2.wait().unwrap() {
        JobResult::Gmr { x } => assert_eq!(x.shape(), (6, 5)),
        _ => panic!("wrong result kind"),
    }
    match h3.wait().unwrap() {
        JobResult::Spsd { idx, c, x, entries_observed } => {
            assert_eq!(idx.len(), 8);
            assert_eq!(c.shape(), (100, 8));
            assert_eq!(x.shape(), (8, 8));
            assert_eq!(entries_observed, 100 * 8 + 40 * 40);
        }
        _ => panic!("wrong result kind"),
    }
    match h4.wait().unwrap() {
        JobResult::Svd { u, sigma, v } => {
            assert_eq!(u.rows(), 80);
            assert_eq!(v.rows(), 60);
            assert!(!sigma.is_empty());
        }
        _ => panic!("wrong result kind"),
    }
    match h5.wait().unwrap() {
        JobResult::Cur { cur } => {
            assert_eq!(cur.c.shape(), (80, 9));
            assert_eq!(cur.u.shape(), (9, 7));
            assert_eq!(cur.r.shape(), (7, 60));
            assert_eq!(cur.col_idx.len(), 9);
            assert_eq!(cur.row_idx.len(), 7);
            let res = cur.residual(crate::gmr::Input::Dense(&a));
            assert!(res.is_finite() && res < a.fro_norm(), "router CUR residual {res} not sane");
        }
        _ => panic!("wrong result kind"),
    }
    match h6.wait().unwrap() {
        JobResult::Cur { cur } => {
            assert_eq!(cur.c.shape(), (80, 9));
            assert_eq!(cur.u.shape(), (9, 7));
            assert_eq!(cur.r.shape(), (7, 60));
            let res = cur.residual(crate::gmr::Input::Dense(&a));
            assert!(res.is_finite() && res < a.fro_norm(), "streaming CUR residual {res} not sane");
        }
        _ => panic!("wrong result kind"),
    }
    assert_eq!(router.metrics.get("router.gmr.completed"), 1);
    assert_eq!(router.metrics.get("router.spsd.completed"), 1);
    assert_eq!(router.metrics.get("router.svd.completed"), 1);
    assert_eq!(router.metrics.get("router.cur.completed"), 1);
    assert_eq!(router.metrics.get("router.cur_stream.completed"), 1);
    router.shutdown();
}

/// [`ApproxJob::KINDS`] is the list the router pre-builds its per-kind
/// counter handles from — a variant missing from it would panic
/// executor-side on first dispatch, so pin it against the enum here.
#[test]
fn approx_job_kinds_list_is_exhaustive() {
    let a = test_matrix(10, 8, 1);
    let mut r = rng(2);
    let c = Mat::randn(10, 3, &mut r);
    let rr = Mat::randn(2, 8, &mut r);
    let jobs = [
        ApproxJob::Gmr {
            a: MatrixPayload::Dense(a.clone()),
            c: c.clone(),
            r: rr.clone(),
            cfg: crate::gmr::FastGmrConfig::gaussian(6, 6),
            seed: 0,
        },
        ApproxJob::SpsdKernel { x: Mat::randn(10, 2, &mut r), sigma: 0.4, c: 2, s: 4, seed: 0 },
        ApproxJob::StreamSvd {
            a: MatrixPayload::Dense(a.clone()),
            cfg: FastSpSvdConfig::paper(2, 2, SketchKind::Gaussian),
            block: 4,
            seed: 0,
        },
        ApproxJob::GmrExact { a: MatrixPayload::Dense(a.clone()), c, r: rr },
        ApproxJob::Cur {
            a: MatrixPayload::Dense(a.clone()),
            cfg: crate::cur::CurConfig::fast(3, 3, 2),
            seed: 0,
        },
        ApproxJob::StreamingCur {
            a: MatrixPayload::Dense(a.clone()),
            cfg: crate::cur::StreamingCurConfig::fast(3, 3, 2, 2),
            block: 4,
            seed: 0,
        },
    ];
    let kinds: Vec<&str> = jobs.iter().map(|j| j.kind()).collect();
    assert_eq!(kinds, ApproxJob::KINDS, "ApproxJob::KINDS out of sync with the enum variants");
    for j in &jobs {
        let (rows, cols) = j.dims();
        if j.kind() == "spsd" {
            assert_eq!((rows, cols), (10, 10), "SPSD dims are the implicit n x n kernel");
        } else {
            assert_eq!((rows, cols), (10, 8), "dims must report the payload shape");
        }
        assert!(j.weight() > 0, "{} weight must be positive", j.kind());
    }
}

#[test]
fn router_many_concurrent_jobs() {
    let router = Router::new(3);
    let mut handles = Vec::new();
    for seed in 0..12u64 {
        let a = test_matrix(40, 30, 100 + seed);
        let mut r = rng(seed);
        let g_c = Mat::randn(30, 4, &mut r);
        let c = crate::linalg::matmul(&a, &g_c);
        let g_r = Mat::randn(3, 40, &mut r);
        let rr = crate::linalg::matmul(&g_r, &a);
        let h = router.submit(ApproxJob::Gmr {
            a: MatrixPayload::Dense(a),
            c,
            r: rr,
            cfg: crate::gmr::FastGmrConfig::gaussian(24, 24),
            seed,
        });
        handles.push(h.unwrap());
    }
    for h in handles {
        assert!(matches!(h.wait().unwrap(), JobResult::Gmr { .. }));
    }
    assert_eq!(router.metrics.get("router.gmr.completed"), 12);
}

#[test]
fn tiled_oracle_matches_plain_and_counts() {
    let mut r = rng(10);
    let x = Mat::randn(50, 5, &mut r);
    let backend = CpuBackend;
    let tiled = TiledKernelOracle::new(&x, 0.5, &backend, 16);
    let plain = RbfOracle::new(&x, 0.5);
    let rows: Vec<usize> = (0..37).collect();
    let cols: Vec<usize> = (5..45).collect();
    let got = tiled.block(&rows, &cols);
    let want = plain.block(&rows, &cols);
    assert_close(&got, &want, 1e-12, "tiled oracle");
    assert_eq!(tiled.entries_requested(), (37 * 40) as u64);
    // ceil(37/16) * ceil(40/16) tiles.
    assert_eq!(tiled.tiles_executed(), 3 * 3);
}

#[test]
fn tiled_oracle_drives_algorithm2() {
    let mut r = rng(11);
    let x = crate::data::synth_clustered(150, 8, 6, 0.4, &mut r);
    let backend = CpuBackend;
    let tiled = TiledKernelOracle::new(&x, 0.5, &backend, 32);
    let sol = crate::spsd::faster_spsd(&tiled, &crate::spsd::FasterSpsdConfig { c: 10, s: 50 }, &mut r);
    assert_eq!(sol.x.shape(), (10, 10));
    assert_eq!(tiled.entries_requested(), (150 * 10 + 50 * 50) as u64);
    // Against the dense oracle the result must agree given the same rng.
    let k = crate::data::rbf_kernel(&x, 0.5);
    let dense = DenseKernelOracle { k: &k };
    let mut r2 = rng(11);
    // Reconstruct the same draw sequence: synth_clustered + faster_spsd
    // consumed from r; replay by re-deriving.
    let _ = crate::data::synth_clustered(150, 8, 6, 0.4, &mut r2);
    let sol2 = crate::spsd::faster_spsd(&dense, &crate::spsd::FasterSpsdConfig { c: 10, s: 50 }, &mut r2);
    assert_close(&sol.x, &sol2.x, 1e-9, "tiled vs dense oracle end-to-end");
}

#[test]
fn payload_helpers() {
    let a = test_matrix(10, 8, 12);
    let p = MatrixPayload::Dense(a);
    assert_eq!(p.rows(), 10);
    assert_eq!(p.cols(), 8);
    assert_eq!(jobs::default_kind_for(&p).name(), "gaussian");
    let sp = MatrixPayload::Sparse(crate::sparse::Csr::from_triplets(4, 4, vec![]));
    assert_eq!(jobs::default_kind_for(&sp).name(), "count");
}

// ---- serving layer: admission, deadlines, cache, batching -----------

use crate::coordinator::router::ServeConfig;
use crate::error::FgError;
use std::time::Duration;

/// A job heavy enough (hundreds-of-ms scale) to occupy a single worker
/// while the test submits fast follow-ups — the timing anchor for the
/// admission/deadline/batching tests.
fn slow_job(seed: u64) -> ApproxJob {
    ApproxJob::StreamSvd {
        a: MatrixPayload::Dense(test_matrix(260, 240, seed)),
        cfg: FastSpSvdConfig::paper(10, 8, SketchKind::Gaussian),
        block: 32,
        seed,
    }
}

fn quick_cur_job(a: &Mat, seed: u64) -> ApproxJob {
    ApproxJob::Cur {
        a: MatrixPayload::Dense(a.clone()),
        cfg: crate::cur::CurConfig::fast(6, 5, 3),
        seed,
    }
}

#[test]
fn submit_sheds_with_overloaded_when_queue_full() {
    let router = Router::with_config(&ServeConfig {
        workers: 1,
        queue_depth: 2,
        ..ServeConfig::service(1)
    });
    let a = test_matrix(50, 40, 60);
    // Occupy the single worker, then overfill the bounded queue.
    let occupier = router.submit(slow_job(61)).unwrap();
    let mut accepted = Vec::new();
    let mut shed = 0;
    for seed in 0..3u64 {
        match router.submit(quick_cur_job(&a, seed)) {
            Ok(h) => accepted.push(h),
            Err(FgError::Overloaded { depth }) => {
                assert_eq!(depth, 2, "shed error must report the configured bound");
                shed += 1;
            }
            Err(e) => panic!("unexpected error kind: {e}"),
        }
    }
    assert!(shed >= 1, "a 3rd submit against a depth-2 queue must shed");
    assert_eq!(router.metrics.get("serve.shed"), shed);
    // Shedding must not corrupt the queue: everything accepted completes.
    assert!(matches!(occupier.wait().unwrap(), JobResult::Svd { .. }));
    let accepted_n = accepted.len() as u64;
    for h in accepted {
        assert!(matches!(h.wait().unwrap(), JobResult::Cur { .. }));
    }
    assert_eq!(router.metrics.get("router.cur.completed"), accepted_n);
    assert!(router.metrics.get("serve.queue.peak") <= 2);
}

#[test]
fn deadline_expired_jobs_fail_cleanly() {
    let router = Router::with_config(&ServeConfig::service(1));
    let a = test_matrix(50, 40, 62);
    let occupier = router.submit(slow_job(63)).unwrap();
    // Expires in the queue while the occupier holds the worker.
    let doomed = router.submit_with_deadline(quick_cur_job(&a, 0), Some(Duration::from_millis(1)));
    let alive = router.submit(quick_cur_job(&a, 1)).unwrap();
    match doomed.unwrap().wait() {
        Err(FgError::DeadlineExceeded { waited_ms }) => {
            assert!(waited_ms >= 1, "expired job must report its queue wait");
        }
        Err(e) => panic!("expected DeadlineExceeded, got error: {e}"),
        Ok(_) => panic!("expected DeadlineExceeded, got a result"),
    }
    // The executor survives: jobs behind the expired one still complete.
    assert!(matches!(alive.wait().unwrap(), JobResult::Cur { .. }));
    assert!(matches!(occupier.wait().unwrap(), JobResult::Svd { .. }));
    assert_eq!(router.metrics.get("serve.deadline_expired"), 1);
    assert_eq!(router.metrics.get("router.cur.completed"), 1, "expired jobs never execute");

    // Caller-side timeout: waiting stops, the job itself still runs.
    let slow = router.submit(slow_job(64)).unwrap();
    match slow.wait_timeout(Duration::from_millis(1)) {
        Err(FgError::DeadlineExceeded { waited_ms }) => assert_eq!(waited_ms, 1),
        Err(e) => panic!("expected wait_timeout to expire, got error: {e}"),
        Ok(_) => panic!("expected wait_timeout to expire, got a result"),
    }
    router.shutdown();
}

#[test]
fn panicking_job_does_not_poison_the_executor() {
    let router = Router::with_config(&ServeConfig::service(1));
    let a = test_matrix(40, 30, 65);
    // C has the wrong row count: solve_exact asserts, the job panics.
    let bad = ApproxJob::GmrExact {
        a: MatrixPayload::Dense(a.clone()),
        c: Mat::zeros(12, 4),
        r: Mat::zeros(3, 30),
    };
    let h_bad = router.submit(bad).unwrap();
    match h_bad.wait() {
        Err(FgError::Runtime(msg)) => {
            assert!(msg.contains("panicked"), "panic must surface as a Runtime error: {msg}")
        }
        Err(e) => panic!("expected a Runtime error from the panicking job, got: {e}"),
        Ok(_) => panic!("expected a Runtime error from the panicking job, got a result"),
    }
    // Same worker thread keeps serving.
    let h_ok = router.submit(quick_cur_job(&a, 2)).unwrap();
    assert!(matches!(h_ok.wait().unwrap(), JobResult::Cur { .. }));
    assert_eq!(router.metrics.get("router.gmr_exact.completed"), 1);
    assert_eq!(router.metrics.get("router.cur.completed"), 1);
}

#[test]
fn cache_hit_returns_bitwise_identical_result() {
    let router = Router::with_config(&ServeConfig {
        workers: 2,
        cache_bytes: 64 << 20,
        ..ServeConfig::service(2)
    });
    let a = test_matrix(80, 60, 66);
    let job = |seed| ApproxJob::Cur {
        a: MatrixPayload::Dense(a.clone()),
        cfg: crate::cur::CurConfig::fast(8, 6, 3),
        seed,
    };
    let JobResult::Cur { cur: cold } = router.submit(job(5)).unwrap().wait().unwrap() else {
        panic!("wrong result kind")
    };
    let JobResult::Cur { cur: warm } = router.submit(job(5)).unwrap().wait().unwrap() else {
        panic!("wrong result kind")
    };
    assert_eq!(router.metrics.get("serve.cache.hits"), 1);
    assert_eq!(router.metrics.get("serve.cache.misses"), 1);
    assert_eq!(router.metrics.get("router.cur.completed"), 1, "the hit must not execute");
    // The serving contract: a hit is a clone of the stored artifact, so
    // it is *bitwise* identical to the cold compute.
    assert_eq!(cold.col_idx, warm.col_idx);
    assert_eq!(cold.row_idx, warm.row_idx);
    assert_eq!(cold.c.data(), warm.c.data());
    assert_eq!(cold.u.data(), warm.u.data());
    assert_eq!(cold.r.data(), warm.r.data());
    // A different seed is a different key: miss, not a stale hit.
    assert!(matches!(router.submit(job(6)).unwrap().wait().unwrap(), JobResult::Cur { .. }));
    assert_eq!(router.metrics.get("serve.cache.hits"), 1);
    assert_eq!(router.metrics.get("serve.cache.misses"), 2);
    assert_eq!(router.metrics.get("serve.cache.entries"), 2);
    let manifest = router.cache_manifest().expect("cache enabled");
    assert!(manifest.contains("2 entries"), "{manifest}");
    assert!(manifest.contains("cur_"), "{manifest}");
}

#[test]
fn batch_window_coalesces_identical_inflight_jobs() {
    let router = Router::with_config(&ServeConfig {
        workers: 1,
        batch_window: Duration::from_secs(5),
        ..ServeConfig::service(1)
    });
    let a = test_matrix(70, 50, 67);
    // The occupier pins the single worker, so the leader below stays
    // in-flight (queued) while the two followers coalesce onto it.
    let occupier = router.submit(slow_job(68)).unwrap();
    let leader = router.submit(quick_cur_job(&a, 9)).unwrap();
    let follower1 = router.submit(quick_cur_job(&a, 9)).unwrap();
    let follower2 = router.submit(quick_cur_job(&a, 9)).unwrap();
    assert_eq!(router.metrics.get("serve.batch.coalesced"), 2);
    assert!(matches!(occupier.wait().unwrap(), JobResult::Svd { .. }));
    let JobResult::Cur { cur: lead } = leader.wait().unwrap() else { panic!("wrong kind") };
    let JobResult::Cur { cur: f1 } = follower1.wait().unwrap() else { panic!("wrong kind") };
    let JobResult::Cur { cur: f2 } = follower2.wait().unwrap() else { panic!("wrong kind") };
    // One execution fanned out to all three waiters, bitwise.
    assert_eq!(router.metrics.get("router.cur.completed"), 1);
    for got in [&f1, &f2] {
        assert_eq!(lead.col_idx, got.col_idx);
        assert_eq!(lead.c.data(), got.c.data());
        assert_eq!(lead.u.data(), got.u.data());
        assert_eq!(lead.r.data(), got.r.data());
    }
}
