//! Layer-3 coordinator: the streaming orchestrator and approximation-job
//! service that wrap the paper's algorithms into a deployable system.
//!
//! * [`pipeline`] — concurrent single-pass pipelines for Algorithm 3
//!   SVD and for streaming CUR: reader → bounded block batches
//!   dispatched on the [`crate::parallel`] pool → deterministic
//!   stream-ordered accumulator fold. Both match their single-threaded
//!   references in [`crate::svdstream`] / [`crate::cur::streaming`]
//!   (tested).
//! * [`router`] — a job service: clients submit [`jobs::ApproxJob`]s,
//!   worker threads execute them against a [`crate::compute::Backend`].
//! * [`batcher`] — tiles kernel-entry requests into fixed-shape
//!   `rbf_block` executions (the Algorithm 2 entry oracle, production
//!   form) with per-tile padding and entry accounting.

pub mod batcher;
pub mod jobs;
pub mod pipeline;
pub mod router;

pub use batcher::TiledKernelOracle;
pub use jobs::{ApproxJob, JobResult};
pub use pipeline::{PipelineConfig, StreamPipeline};
pub use router::{JobHandle, Router};

#[cfg(test)]
mod tests;
