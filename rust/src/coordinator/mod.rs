//! Layer-3 coordinator: the streaming orchestrator and approximation-job
//! service that wrap the paper's algorithms into a deployable system.
//!
//! * [`pipeline`] — concurrent single-pass pipeline for Algorithm 3:
//!   reader → bounded block batches dispatched on the
//!   [`crate::parallel`] pool → deterministic slot-ordered accumulator
//!   fold. Matches the single-threaded reference in
//!   [`crate::svdstream`] (tested).
//! * [`router`] — a job service: clients submit [`jobs::ApproxJob`]s,
//!   worker threads execute them against a [`crate::compute::Backend`].
//! * [`batcher`] — tiles kernel-entry requests into fixed-shape
//!   `rbf_block` executions (the Algorithm 2 entry oracle, production
//!   form) with per-tile padding and entry accounting.

pub mod batcher;
pub mod jobs;
pub mod pipeline;
pub mod router;

pub use batcher::TiledKernelOracle;
pub use jobs::{ApproxJob, JobResult};
pub use pipeline::{PipelineConfig, StreamPipeline};
pub use router::{JobHandle, Router};

#[cfg(test)]
mod tests;
