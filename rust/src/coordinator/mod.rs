//! Layer-3 coordinator: the streaming orchestrator and approximation-job
//! serving layer that wrap the paper's algorithms into a deployable
//! system.
//!
//! * [`pipeline`] — concurrent single-pass pipelines for Algorithm 3
//!   SVD and for streaming CUR: reader → bounded block batches
//!   dispatched on the [`crate::parallel`] pool → deterministic
//!   stream-ordered accumulator fold. Both match their single-threaded
//!   references in [`crate::svdstream`] / [`crate::cur::streaming`]
//!   (tested).
//! * [`router`] — the serving daemon: clients submit
//!   [`jobs::ApproxJob`]s through admission control (bounded queue,
//!   load shedding, deadlines), cross-request batching, and a
//!   fingerprint-keyed artifact cache; worker threads execute misses
//!   against a [`crate::compute::Backend`].
//! * [`cache`] — dataset/config fingerprints ([`cache::CacheKey`]) and
//!   the LRU byte-budgeted [`cache::ArtifactCache`] of completed
//!   [`jobs::JobResult`]s.
//! * [`batcher`] — coalesces work: identical in-flight serving requests
//!   onto one execution ([`batcher::Batcher`]), and kernel-entry
//!   requests into fixed-shape `rbf_block` tiles (the Algorithm 2 entry
//!   oracle, production form).

pub mod batcher;
pub mod cache;
pub mod jobs;
pub mod pipeline;
pub mod router;

pub use batcher::{Batcher, TiledKernelOracle};
pub use cache::{job_key, ArtifactCache, CacheKey, Lookup, WarmStartStats};
pub use jobs::{ApproxJob, JobResult, MatrixPayload};
pub use pipeline::{PipelineConfig, StreamPipeline};
pub use router::{JobHandle, Router, ServeConfig};

#[cfg(test)]
mod tests;
