//! Concurrent single-pass SVD pipeline (Algorithm 3 as a streaming
//! system).
//!
//! ```text
//! reader ──(bounded channel: backpressure)──▶ worker₀ ─┐
//!                                            worker₁ ─┼─▶ fold ─▶ finalize
//!                                            …        ─┘
//! ```
//!
//! * The reader owns the [`ColumnStream`] and never buffers more than
//!   `queue_depth` blocks — O((m+n)·sketch) memory total, the paper's
//!   single-pass guarantee.
//! * Workers hold private accumulators (C, M) and write disjoint column
//!   ranges of R; the fold step sums worker accumulators. All updates
//!   commute, so the result is independent of scheduling (tested against
//!   the single-threaded reference).

use crate::error::{FgError, Result};
use crate::linalg::Mat;
use crate::metrics::Metrics;
use crate::svdstream::fast::{accumulate_block, finalize, FastSpSvdConfig, FastSpSvdSketches};
use crate::svdstream::source::ColumnStream;
use crate::svdstream::SpSvdResult;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

/// Pipeline tuning knobs.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Worker threads (1 is optimal on a 1-core container; kept
    /// configurable for larger machines).
    pub workers: usize,
    /// Bounded-queue depth between reader and workers (backpressure).
    pub queue_depth: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self { workers: 1, queue_depth: 4 }
    }
}

/// The streaming pipeline.
pub struct StreamPipeline {
    cfg: PipelineConfig,
    pub metrics: Arc<Metrics>,
}

struct WorkerState {
    c_acc: Mat,
    r_acc: Mat,
    m_acc: Mat,
    blocks: usize,
}

impl StreamPipeline {
    pub fn new(cfg: PipelineConfig) -> Self {
        assert!(cfg.workers >= 1 && cfg.queue_depth >= 1);
        Self { cfg, metrics: Arc::new(Metrics::new()) }
    }

    /// Run Algorithm 3 over the stream with pre-drawn sketches.
    ///
    /// The stream is consumed exactly once; blocks are moved through the
    /// bounded channel and dropped after their worker processes them.
    pub fn run(
        &self,
        stream: &mut dyn ColumnStream,
        cfg: &FastSpSvdConfig,
        sketches: &FastSpSvdSketches,
    ) -> Result<SpSvdResult> {
        let (m, n) = (stream.rows(), stream.cols());
        let workers = self.cfg.workers;
        let (tx, rx) = mpsc::sync_channel::<(usize, Mat)>(self.cfg.queue_depth);
        let rx = Arc::new(std::sync::Mutex::new(rx));
        let processed = Arc::new(AtomicUsize::new(0));
        let max_inflight = Arc::new(AtomicUsize::new(0));
        let inflight = Arc::new(AtomicUsize::new(0));

        let states: Vec<WorkerState> = std::thread::scope(|scope| -> Result<Vec<WorkerState>> {
            let mut handles = Vec::with_capacity(workers);
            for _ in 0..workers {
                let rx = rx.clone();
                let processed = processed.clone();
                let inflight = inflight.clone();
                let metrics = self.metrics.clone();
                handles.push(scope.spawn(move || {
                    let mut st = WorkerState {
                        c_acc: Mat::zeros(m, cfg.c),
                        r_acc: Mat::zeros(cfg.r, n),
                        m_acc: Mat::zeros(cfg.s_c, cfg.s_r),
                        blocks: 0,
                    };
                    loop {
                        let msg = rx.lock().unwrap().recv();
                        let Ok((col_start, block)) = msg else { break };
                        inflight.fetch_sub(1, Ordering::Relaxed);
                        let c1 = col_start + block.cols();
                        metrics.time("pipeline.block_update", || {
                            accumulate_block(
                                &block,
                                col_start,
                                c1,
                                sketches,
                                &mut st.c_acc,
                                &mut st.r_acc,
                                &mut st.m_acc,
                            );
                        });
                        st.blocks += 1;
                        processed.fetch_add(1, Ordering::Relaxed);
                        metrics.add("pipeline.blocks", 1);
                        metrics.add("pipeline.cols", block.cols() as u64);
                    }
                    st
                }));
            }

            // Reader loop (current thread): owns the stream, applies
            // backpressure via the bounded channel.
            let mut sent = 0usize;
            while let Some(block) = stream.next_block() {
                let depth = inflight.fetch_add(1, Ordering::Relaxed) + 1;
                max_inflight.fetch_max(depth, Ordering::Relaxed);
                tx.send((block.col_start, block.data))
                    .map_err(|_| FgError::Coordinator("workers exited early".into()))?;
                sent += 1;
            }
            drop(tx);
            self.metrics.add("pipeline.blocks_sent", sent as u64);

            let mut states = Vec::with_capacity(workers);
            for h in handles {
                states.push(h.join().map_err(|_| FgError::Coordinator("worker panicked".into()))?);
            }
            Ok(states)
        })?;

        self.metrics.add("pipeline.max_queue_depth", max_inflight.load(Ordering::Relaxed) as u64);

        // Fold worker accumulators (all updates commute).
        let mut c_acc = Mat::zeros(m, cfg.c);
        let mut r_acc = Mat::zeros(cfg.r, n);
        let mut m_acc = Mat::zeros(cfg.s_c, cfg.s_r);
        let mut blocks = 0usize;
        for st in states {
            c_acc += &st.c_acc;
            r_acc += &st.r_acc;
            m_acc += &st.m_acc;
            blocks += st.blocks;
        }
        debug_assert_eq!(blocks, processed.load(Ordering::Relaxed));

        let (u, sigma, v) =
            self.metrics.time("pipeline.finalize", || finalize(cfg, sketches, &c_acc, &r_acc, &m_acc));
        Ok(SpSvdResult { u, sigma, v, blocks })
    }

    /// Maximum queue depth observed in the last run (backpressure bound).
    pub fn max_queue_depth(&self) -> u64 {
        self.metrics.get("pipeline.max_queue_depth")
    }
}
