//! Concurrent single-pass pipelines: Algorithm 3 as a streaming system
//! ([`StreamPipeline::run`]) and streaming CUR on the same
//! double-buffered reader ([`StreamPipeline::run_cur`]).
//!
//! ```text
//! reader ──(batch of ≤ slots blocks)──▶ pool worker₀ ─┐
//!                                       pool worker₁ ─┼─▶ fold ─▶ finalize
//!                                       …             ─┘
//! ```
//!
//! * The reader owns the [`ColumnStream`] and never buffers more than
//!   two batches (≤ `slots` blocks each: the one being accumulated and
//!   the one being prefetched) — O(slots·(m+n)·sketch) memory total
//!   (the paper's single-pass guarantee, scaled by the slot count,
//!   which `queue_depth` bounds in auto mode). Batches are
//!   **double-buffered**: the current batch's slot updates run on a
//!   scoped compute thread while the reader thread pulls the next batch
//!   from the stream, so an I/O-bound stream overlaps with compute.
//!   Batch boundaries depend only on stream order and the slot count —
//!   the overlap cannot change any slot's block subsequence.
//! * Per-block stream updates are dispatched to the `crate::parallel`
//!   pool: block `j` of a batch lands in accumulator slot `j`, so each
//!   slot folds a fixed, scheduling-independent subsequence of blocks in
//!   stream order, and slots are reduced in ascending order at the end.
//!   The result is therefore **deterministic** for a given worker count
//!   (updates commute exactly in ℝ; in floating point the slot fold
//!   regroups sums, which the tests pin at ≤ 1e-8 against the
//!   single-threaded reference). `workers = 1` reproduces the serial
//!   fold bitwise.

use crate::cur::streaming::{
    self as curstream, StreamState, StreamingCurConfig, StreamingCurResult, StreamingCurSketches,
};
use crate::error::{panic_message, FgError, Result};
use crate::faults::RetryPolicy;
use crate::linalg::Mat;
use crate::metrics::Metrics;
use crate::parallel::{self, Pool};
use crate::rng::Pcg64;
use crate::svdstream::fast::{accumulate_block_with, finalize, FastSpSvdConfig, FastSpSvdSketches};
use crate::svdstream::source::ColumnStream;
use crate::svdstream::SpSvdResult;
use std::sync::Arc;

/// Pipeline tuning knobs.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Accumulator slots / pool workers for block updates. 0 means "use
    /// the process-wide `threads` knob" (see `crate::parallel`); 1
    /// reproduces the single-threaded fold bitwise.
    pub workers: usize,
    /// Backpressure/memory bound: caps the auto-resolved slot count
    /// (`workers == 0`), and with it both in-flight blocks and
    /// accumulator memory (O(slots·(m+n)·sketch)). An explicit `workers`
    /// is honored exactly; with double-buffered batches the pipeline
    /// holds at most `2·workers` blocks alive (the batch being
    /// accumulated plus the prefetched one) — still tighter than the old
    /// channel's per-block queue for typical depths.
    pub queue_depth: usize,
    /// Retry policy for transient stream-read errors. The reader
    /// retries *within the current block* with capped exponential
    /// backoff — sketch/reservoir state is untouched by a retry, so
    /// the single-pass contract holds (see
    /// [`ColumnStream::next_block`]).
    pub retry: RetryPolicy,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self { workers: 0, queue_depth: 4, retry: RetryPolicy::default() }
    }
}

/// The streaming pipeline.
pub struct StreamPipeline {
    cfg: PipelineConfig,
    pub metrics: Arc<Metrics>,
}

struct SlotState {
    c_acc: Mat,
    r_acc: Mat,
    m_acc: Mat,
    blocks: usize,
}

impl StreamPipeline {
    pub fn new(cfg: PipelineConfig) -> Self {
        assert!(cfg.queue_depth >= 1);
        Self { cfg, metrics: Arc::new(Metrics::new()) }
    }

    /// Worker/slot count. `threads = 1` forces one slot — the bitwise
    /// single-threaded contract of the CLI `--threads 1` overrides even
    /// an explicit `workers`. Otherwise an explicit `workers` is honored
    /// exactly, and the auto default (`workers == 0`) resolves to the
    /// `threads` knob capped by `queue_depth`, so accumulator memory —
    /// O(slots·(m+n)·sketch) — stays bounded by a documented knob on
    /// many-core hosts instead of silently scaling with the machine.
    fn slots(&self) -> usize {
        if parallel::threads() <= 1 {
            1
        } else if self.cfg.workers == 0 {
            parallel::threads().min(self.cfg.queue_depth).max(1)
        } else {
            self.cfg.workers
        }
    }

    /// Run Algorithm 3 over the stream with pre-drawn sketches.
    ///
    /// The stream is consumed exactly once; blocks are moved into a
    /// batch, dispatched to the pool, and dropped once their slot has
    /// accumulated them.
    pub fn run(
        &self,
        stream: &mut dyn ColumnStream,
        cfg: &FastSpSvdConfig,
        sketches: &FastSpSvdSketches,
    ) -> Result<SpSvdResult> {
        let (m, n) = (stream.rows(), stream.cols());
        let slots = self.slots();
        let pool = Pool::new(slots);
        let mut states: Vec<SlotState> = (0..slots)
            .map(|_| SlotState {
                c_acc: Mat::zeros(m, cfg.c),
                r_acc: Mat::zeros(cfg.r, n),
                m_acc: Mat::zeros(cfg.s_c, cfg.s_r),
                blocks: 0,
            })
            .collect();

        // The calling thread's effective worker budget, captured once up
        // front: the budget is thread-local and would NOT be visible from
        // the compute thread the double-buffered loop spawns.
        let budget = parallel::threads();

        // One span per run, opened on this (driver) thread: the batch
        // slot updates run on scoped compute threads with no installed
        // collector, so span structure stays knob-invariant.
        let mut stream_span = crate::obs::span("pipeline.stream", crate::obs::cat::STREAM);
        let mut sent = 0usize;
        let mut max_inflight = 0usize;
        let mut batch = read_batch(stream, slots, &self.cfg.retry, &self.metrics)?;
        while !batch.is_empty() {
            sent += batch.len();
            max_inflight = max_inflight.max(batch.len());
            let batch_cols: u64 = batch.iter().map(|(_, b)| b.cols() as u64).sum();
            let batch_len = batch.len() as u64;
            let used = batch.len();

            // Double-buffered batches: the current batch's slot updates
            // run on a scoped compute thread while this (reader) thread
            // prefetches the next batch, so an I/O-bound stream overlaps
            // with compute. Deterministic slot assignment is unchanged:
            // batch entry j → slot j, and each occupied slot's sketch
            // applies split the captured thread budget (remainder-aware,
            // so slots × inner fills the knob without nested regions
            // oversubscribing the machine — short final batches hand the
            // freed budget to the slots still working). The inner count
            // depends only on the knob, the batch length, and the slot
            // index, never on scheduling.
            //
            // One timing sample per *batch* (≤ slots blocks), hence the
            // metric name; with the overlap it covers max(compute, read)
            // for the batch — per-block latency is this divided by the
            // batch size, not comparable to a per-block timer.
            let states_ref: &mut [SlotState] = &mut states;
            let (update_res, next) = self.metrics.time("pipeline.batch_update", || {
                std::thread::scope(|scope| {
                    let compute = scope.spawn(move || {
                        let mut units: Vec<(&mut SlotState, (usize, Mat))> =
                            states_ref.iter_mut().zip(batch.into_iter()).collect();
                        pool.for_each_mut(&mut units, |slot, unit| {
                            let inner = if used > 1 {
                                Pool::new(
                                    (budget / used + usize::from(slot < budget % used)).max(1),
                                )
                            } else {
                                Pool::new(budget)
                            };
                            let (state, payload) = unit;
                            let col_start = payload.0;
                            let block = &payload.1;
                            let c1 = col_start + block.cols();
                            accumulate_block_with(
                                block,
                                col_start,
                                c1,
                                sketches,
                                &inner,
                                &mut state.c_acc,
                                &mut state.r_acc,
                                &mut state.m_acc,
                            );
                            state.blocks += 1;
                        });
                    });
                    let next = read_batch(stream, slots, &self.cfg.retry, &self.metrics);
                    (compute.join(), next)
                })
            });
            update_res.map_err(|p| {
                FgError::Coordinator(format!(
                    "worker panicked during block update: {}",
                    panic_message(p)
                ))
            })?;
            self.metrics.add("pipeline.blocks", batch_len);
            self.metrics.add("pipeline.cols", batch_cols);
            batch = next?;
        }
        stream_span.meta("blocks", sent);
        drop(stream_span);
        self.metrics.add("pipeline.blocks_sent", sent as u64);
        self.metrics.add("pipeline.max_queue_depth", max_inflight as u64);

        // Fold slot accumulators in ascending slot order (deterministic).
        let mut c_acc = Mat::zeros(m, cfg.c);
        let mut r_acc = Mat::zeros(cfg.r, n);
        let mut m_acc = Mat::zeros(cfg.s_c, cfg.s_r);
        let mut blocks = 0usize;
        for st in &states {
            c_acc += &st.c_acc;
            r_acc += &st.r_acc;
            m_acc += &st.m_acc;
            blocks += st.blocks;
        }
        debug_assert_eq!(blocks, sent);

        let fin_span = crate::obs::span("pipeline.finalize", crate::obs::cat::STREAM);
        let (u, sigma, v) = self
            .metrics
            .time("pipeline.finalize", || finalize(cfg, sketches, &c_acc, &r_acc, &m_acc));
        drop(fin_span);
        Ok(SpSvdResult { u, sigma, v, blocks })
    }

    /// Maximum *batch* size observed in the last run. With
    /// double-buffering, peak resident blocks ≈ 2x this (current batch +
    /// prefetched batch).
    pub fn max_queue_depth(&self) -> u64 {
        self.metrics.get("pipeline.max_queue_depth")
    }

    /// Single-pass streaming CUR on the same double-buffered reader as
    /// [`StreamPipeline::run`]: the current batch's blocks are sketched
    /// concurrently on the pool (each slot splitting the thread budget
    /// like the SVD path) while this thread prefetches the next batch.
    ///
    /// Unlike the SVD fold, the CUR fold is **driver-side and strictly
    /// in stream order** — `Y` writes are disjoint, `Z` adds happen
    /// block-by-block in stream position, and the reservoir's rng draws
    /// consume `rng` in column order. The result is therefore *bitwise*
    /// identical to [`crate::cur::streaming::streaming_cur_with`] for
    /// every worker/thread count when the sketch family is bitwise
    /// (Gaussian/SRHT), which the coordinator tests pin.
    pub fn run_cur(
        &self,
        stream: &mut dyn ColumnStream,
        cfg: &StreamingCurConfig,
        sketches: &StreamingCurSketches,
        rng: &mut Pcg64,
    ) -> Result<StreamingCurResult> {
        let (m, n) = (stream.rows(), stream.cols());
        let slots = self.slots();
        let pool = Pool::new(slots);
        let mut state = StreamState::new(cfg, sketches, m, n);

        // The calling thread's effective worker budget, captured once up
        // front (thread-local — invisible from the compute thread).
        let budget = parallel::threads();

        // Driver-side span (compute threads have no collector), so the
        // recorded structure is identical at every knob setting.
        let mut stream_span = crate::obs::span("pipeline.stream", crate::obs::cat::STREAM);
        let mut sent = 0usize;
        let mut batch = read_batch(stream, slots, &self.cfg.retry, &self.metrics)?;
        while !batch.is_empty() {
            sent += batch.len();
            let batch_cols: u64 = batch.iter().map(|(_, b)| b.cols() as u64).sum();
            let batch_len = batch.len() as u64;
            let used = batch.len();
            // Sketch the batch's blocks on a scoped compute thread while
            // this thread prefetches the next batch; fold after the join.
            let (sketched, next) = self.metrics.time("pipeline.cur_batch", || {
                std::thread::scope(|scope| {
                    let compute = scope.spawn(move || {
                        let mut work: Vec<(Option<curstream::BlockSketch>, (usize, Mat))> =
                            batch.into_iter().map(|b| (None, b)).collect();
                        pool.for_each_mut(&mut work, |slot, unit| {
                            let inner = if used > 1 {
                                Pool::new(
                                    (budget / used + usize::from(slot < budget % used)).max(1),
                                )
                            } else {
                                Pool::new(budget)
                            };
                            let (dst, (col_start, block)) = unit;
                            let data = std::mem::replace(block, Mat::zeros(0, 0));
                            *dst =
                                Some(curstream::sketch_block(*col_start, data, sketches, &inner));
                        });
                        work
                    });
                    let next = read_batch(stream, slots, &self.cfg.retry, &self.metrics);
                    (compute.join(), next)
                })
            });
            let sketched = sketched.map_err(|p| {
                FgError::Coordinator(format!(
                    "worker panicked during block sketch: {}",
                    panic_message(p)
                ))
            })?;
            for (bs, _) in sketched {
                state.fold(bs.expect("every batch entry is sketched"), rng);
            }
            self.metrics.add("pipeline.cur_blocks", batch_len);
            self.metrics.add("pipeline.cur_cols", batch_cols);
            batch = next?;
        }
        stream_span.meta("blocks", sent);
        drop(stream_span);
        self.metrics.set("pipeline.cur_reservoir_candidates", state.candidates() as u64);

        let fin_span = crate::obs::span("pipeline.finalize", crate::obs::cat::STREAM);
        let result = self
            .metrics
            .time("pipeline.cur_finalize", || curstream::finalize(cfg, sketches, state, rng));
        drop(fin_span);
        Ok(result)
    }
}

/// Pull the next batch (≤ `slots` blocks) off the stream. Batch
/// composition depends only on stream order and the slot count — the
/// double-buffered prefetch cannot reorder it.
///
/// Transient read errors are retried *within the current block* under
/// `retry` (capped exponential backoff): a failing `next_block` has not
/// advanced the stream, so the retry re-reads the block the failed call
/// would have yielded, and no downstream sketch or reservoir state is
/// touched in between. Permanent errors (and transient ones that
/// exhaust the attempt budget) propagate.
fn read_batch(
    stream: &mut dyn ColumnStream,
    slots: usize,
    retry: &RetryPolicy,
    metrics: &Metrics,
) -> Result<Vec<(usize, Mat)>> {
    let mut batch = Vec::with_capacity(slots);
    while batch.len() < slots {
        let mut attempt = 1u32;
        let block = loop {
            match stream.next_block() {
                Ok(b) => break b,
                Err(e) if e.is_transient() && attempt < retry.max_attempts => {
                    metrics.add("pipeline.read_retries", 1);
                    std::thread::sleep(retry.backoff(attempt));
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        };
        match block {
            Some(block) => batch.push((block.col_start, block.data)),
            None => break,
        }
    }
    Ok(batch)
}
