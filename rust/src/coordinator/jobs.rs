//! Approximation jobs — the unit of work the router schedules.

use crate::cur::{CurConfig, CurDecomposition, StreamingCurConfig};
use crate::gmr::FastGmrConfig;
use crate::linalg::Mat;
use crate::sketch::SketchKind;
use crate::sparse::Csr;
use crate::svdstream::FastSpSvdConfig;

/// Matrix payload a job carries (owned — jobs cross threads).
pub enum MatrixPayload {
    Dense(Mat),
    Sparse(Csr),
}

impl MatrixPayload {
    pub fn rows(&self) -> usize {
        match self {
            MatrixPayload::Dense(a) => a.rows(),
            MatrixPayload::Sparse(a) => a.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            MatrixPayload::Dense(a) => a.cols(),
            MatrixPayload::Sparse(a) => a.cols(),
        }
    }

    pub fn as_input(&self) -> crate::gmr::Input<'_> {
        match self {
            MatrixPayload::Dense(a) => crate::gmr::Input::Dense(a),
            MatrixPayload::Sparse(a) => crate::gmr::Input::Sparse(a),
        }
    }
}

/// A job submitted to the [`super::Router`].
pub enum ApproxJob {
    /// Fast GMR (Algorithm 1): approximate `min_X ‖A − C X R‖`.
    Gmr { a: MatrixPayload, c: Mat, r: Mat, cfg: FastGmrConfig, seed: u64 },
    /// Faster SPSD (Algorithm 2) on an RBF kernel of the given points.
    SpsdKernel { x: Mat, sigma: f64, c: usize, s: usize, seed: u64 },
    /// Fast single-pass SVD (Algorithm 3) over an owned matrix streamed
    /// in `block`-column chunks.
    StreamSvd { a: MatrixPayload, cfg: FastSpSvdConfig, block: usize, seed: u64 },
    /// Exact GMR baseline (for comparisons through the same service).
    GmrExact { a: MatrixPayload, c: Mat, r: Mat },
    /// CUR decomposition (column/row selection + Fast-GMR core).
    Cur { a: MatrixPayload, cfg: CurConfig, seed: u64 },
    /// Single-pass streaming CUR over an owned matrix streamed in
    /// `block`-column chunks (rank-k subspace leverage selection,
    /// reservoir-retained columns, sketch-resolved core and rows).
    StreamingCur { a: MatrixPayload, cfg: StreamingCurConfig, block: usize, seed: u64 },
}

impl ApproxJob {
    /// Every kind tag [`ApproxJob::kind`] can return, in variant order.
    /// The router pre-creates per-kind counter handles from this list so
    /// its hot path never touches the metrics registry lock.
    pub const KINDS: [&'static str; 6] = ["gmr", "spsd", "svd", "gmr_exact", "cur", "cur_stream"];

    /// Job kind tag (metrics/routing).
    pub fn kind(&self) -> &'static str {
        match self {
            ApproxJob::Gmr { .. } => "gmr",
            ApproxJob::SpsdKernel { .. } => "spsd",
            ApproxJob::StreamSvd { .. } => "svd",
            ApproxJob::GmrExact { .. } => "gmr_exact",
            ApproxJob::Cur { .. } => "cur",
            ApproxJob::StreamingCur { .. } => "cur_stream",
        }
    }

    /// Input dimensions `(rows, cols)` — trace-span metadata. Kernel
    /// jobs report the implicit n×n kernel matrix of their point set.
    pub fn dims(&self) -> (usize, usize) {
        match self {
            ApproxJob::Gmr { a, .. }
            | ApproxJob::StreamSvd { a, .. }
            | ApproxJob::GmrExact { a, .. }
            | ApproxJob::Cur { a, .. }
            | ApproxJob::StreamingCur { a, .. } => (a.rows(), a.cols()),
            ApproxJob::SpsdKernel { x, .. } => (x.rows(), x.rows()),
        }
    }

    /// Rough FLOP weight used by the router's load-aware dispatch.
    pub fn weight(&self) -> u64 {
        match self {
            ApproxJob::Gmr { a, cfg, .. } => (a.rows() + a.cols()) as u64 * (cfg.s_c + cfg.s_r) as u64,
            ApproxJob::SpsdKernel { x, c, s, .. } => x.rows() as u64 * (*c as u64) + (*s as u64).pow(2),
            ApproxJob::StreamSvd { a, cfg, .. } => {
                (a.rows() + a.cols()) as u64 * (cfg.c + cfg.r + cfg.s_c) as u64
            }
            ApproxJob::GmrExact { a, c, r } => {
                a.rows() as u64 * a.cols() as u64 * (c.cols() + r.rows()) as u64
            }
            ApproxJob::Cur { a, cfg, .. } => {
                (a.rows() + a.cols()) as u64 * (cfg.c + cfg.r + cfg.s_c + cfg.s_r) as u64
            }
            ApproxJob::StreamingCur { a, cfg, .. } => {
                (a.rows() + a.cols()) as u64 * (cfg.c + cfg.r + cfg.s_c + cfg.s_r) as u64
            }
        }
    }
}

/// Result of a completed job (clonable: the artifact cache hands copies
/// of a stored result to repeated queries, and the batcher fans one
/// computation out to every coalesced waiter).
#[derive(Clone)]
pub enum JobResult {
    /// GMR core matrix X̃ (c×r) plus the sketch sizes used.
    Gmr { x: Mat },
    /// SPSD factors: sampled column indices, C, PSD core; plus observed
    /// kernel-entry count.
    Spsd { idx: Vec<usize>, c: Mat, x: Mat, entries_observed: u64 },
    /// SVD factors.
    Svd { u: Mat, sigma: Vec<f64>, v: Mat },
    /// CUR factors (selected indices + C, U, R).
    Cur { cur: CurDecomposition },
}

impl JobResult {
    pub fn kind(&self) -> &'static str {
        match self {
            JobResult::Gmr { .. } => "gmr",
            JobResult::Spsd { .. } => "spsd",
            JobResult::Svd { .. } => "svd",
            JobResult::Cur { .. } => "cur",
        }
    }

    /// Output shapes per factor, in the `rows×cols` convention of
    /// [`crate::runtime::artifacts::ManifestEntry`] (index/singular-value
    /// vectors count as `n×1`) — what the artifact cache renders in its
    /// manifest-style inventory.
    pub fn output_shapes(&self) -> Vec<(usize, usize)> {
        match self {
            JobResult::Gmr { x } => vec![x.shape()],
            JobResult::Spsd { idx, c, x, .. } => vec![(idx.len(), 1), c.shape(), x.shape()],
            JobResult::Svd { u, sigma, v } => vec![u.shape(), (sigma.len(), 1), v.shape()],
            JobResult::Cur { cur } => vec![
                (cur.col_idx.len(), 1),
                (cur.row_idx.len(), 1),
                cur.c.shape(),
                cur.u.shape(),
                cur.r.shape(),
            ],
        }
    }

    /// Approximate heap size of the result payload — the unit the
    /// artifact cache's byte budget is accounted in (8 bytes per stored
    /// scalar/index; struct overhead is noise at matrix scale).
    pub fn approx_bytes(&self) -> usize {
        self.output_shapes().iter().map(|(r, c)| r * c * 8).sum()
    }
}

/// Sketch family a service config maps to per payload type (dense →
/// Gaussian, sparse → CountSketch, the §6 convention).
pub fn default_kind_for(payload: &MatrixPayload) -> SketchKind {
    match payload {
        MatrixPayload::Dense(_) => SketchKind::Gaussian,
        MatrixPayload::Sparse(_) => SketchKind::Count,
    }
}
