//! Approximation jobs — the unit of work the router schedules.

use crate::cur::{CurConfig, CurDecomposition, StreamingCurConfig};
use crate::gmr::FastGmrConfig;
use crate::linalg::Mat;
use crate::sketch::SketchKind;
use crate::sparse::Csr;
use crate::svdstream::FastSpSvdConfig;

/// Matrix payload a job carries (owned — jobs cross threads).
pub enum MatrixPayload {
    Dense(Mat),
    Sparse(Csr),
}

impl MatrixPayload {
    pub fn rows(&self) -> usize {
        match self {
            MatrixPayload::Dense(a) => a.rows(),
            MatrixPayload::Sparse(a) => a.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            MatrixPayload::Dense(a) => a.cols(),
            MatrixPayload::Sparse(a) => a.cols(),
        }
    }

    pub fn as_input(&self) -> crate::gmr::Input<'_> {
        match self {
            MatrixPayload::Dense(a) => crate::gmr::Input::Dense(a),
            MatrixPayload::Sparse(a) => crate::gmr::Input::Sparse(a),
        }
    }
}

/// A job submitted to the [`super::Router`].
pub enum ApproxJob {
    /// Fast GMR (Algorithm 1): approximate `min_X ‖A − C X R‖`.
    Gmr { a: MatrixPayload, c: Mat, r: Mat, cfg: FastGmrConfig, seed: u64 },
    /// Faster SPSD (Algorithm 2) on an RBF kernel of the given points.
    SpsdKernel { x: Mat, sigma: f64, c: usize, s: usize, seed: u64 },
    /// Fast single-pass SVD (Algorithm 3) over an owned matrix streamed
    /// in `block`-column chunks.
    StreamSvd { a: MatrixPayload, cfg: FastSpSvdConfig, block: usize, seed: u64 },
    /// Exact GMR baseline (for comparisons through the same service).
    GmrExact { a: MatrixPayload, c: Mat, r: Mat },
    /// CUR decomposition (column/row selection + Fast-GMR core).
    Cur { a: MatrixPayload, cfg: CurConfig, seed: u64 },
    /// Single-pass streaming CUR over an owned matrix streamed in
    /// `block`-column chunks (rank-k subspace leverage selection,
    /// reservoir-retained columns, sketch-resolved core and rows).
    StreamingCur { a: MatrixPayload, cfg: StreamingCurConfig, block: usize, seed: u64 },
}

impl ApproxJob {
    /// Every kind tag [`ApproxJob::kind`] can return, in variant order.
    /// The router pre-creates per-kind counter handles from this list so
    /// its hot path never touches the metrics registry lock.
    pub const KINDS: [&'static str; 6] = ["gmr", "spsd", "svd", "gmr_exact", "cur", "cur_stream"];

    /// Job kind tag (metrics/routing).
    pub fn kind(&self) -> &'static str {
        match self {
            ApproxJob::Gmr { .. } => "gmr",
            ApproxJob::SpsdKernel { .. } => "spsd",
            ApproxJob::StreamSvd { .. } => "svd",
            ApproxJob::GmrExact { .. } => "gmr_exact",
            ApproxJob::Cur { .. } => "cur",
            ApproxJob::StreamingCur { .. } => "cur_stream",
        }
    }

    /// Input dimensions `(rows, cols)` — trace-span metadata. Kernel
    /// jobs report the implicit n×n kernel matrix of their point set.
    pub fn dims(&self) -> (usize, usize) {
        match self {
            ApproxJob::Gmr { a, .. }
            | ApproxJob::StreamSvd { a, .. }
            | ApproxJob::GmrExact { a, .. }
            | ApproxJob::Cur { a, .. }
            | ApproxJob::StreamingCur { a, .. } => (a.rows(), a.cols()),
            ApproxJob::SpsdKernel { x, .. } => (x.rows(), x.rows()),
        }
    }

    /// Re-plan this job at a smaller sketch-size tier: every accuracy
    /// knob (core sketch sizes) is halved, clamped to its structural
    /// minimum (the core solve needs `s_c ≥ c`, `s_r ≥ r`). Output
    /// shapes are untouched — a degraded job answers the same query,
    /// less accurately. Returns `false` when nothing could shrink
    /// (already at minimum, or the kind has no sketch knob — the exact
    /// baseline).
    pub fn degrade_in_place(&mut self) -> bool {
        fn shrink(v: &mut usize, floor: usize) -> bool {
            let next = (*v / 2).max(floor.max(1));
            let changed = next < *v;
            *v = next;
            changed
        }
        match self {
            ApproxJob::Gmr { c, r, cfg, .. } => {
                let sc = shrink(&mut cfg.s_c, c.cols());
                let sr = shrink(&mut cfg.s_r, r.rows());
                sc | sr
            }
            ApproxJob::SpsdKernel { c, s, .. } => shrink(s, *c),
            ApproxJob::StreamSvd { cfg, .. } => {
                let sc = shrink(&mut cfg.s_c, cfg.c);
                let sr = shrink(&mut cfg.s_r, cfg.r);
                sc | sr
            }
            ApproxJob::GmrExact { .. } => false,
            ApproxJob::Cur { cfg, .. } => {
                let sc = shrink(&mut cfg.s_c, cfg.c);
                let sr = shrink(&mut cfg.s_r, cfg.r);
                sc | sr
            }
            ApproxJob::StreamingCur { cfg, .. } => {
                let sc = shrink(&mut cfg.s_c, cfg.c);
                let sr = shrink(&mut cfg.s_r, cfg.r);
                sc | sr
            }
        }
    }

    /// Rough FLOP weight used by the router's load-aware dispatch.
    pub fn weight(&self) -> u64 {
        match self {
            ApproxJob::Gmr { a, cfg, .. } => (a.rows() + a.cols()) as u64 * (cfg.s_c + cfg.s_r) as u64,
            ApproxJob::SpsdKernel { x, c, s, .. } => x.rows() as u64 * (*c as u64) + (*s as u64).pow(2),
            ApproxJob::StreamSvd { a, cfg, .. } => {
                (a.rows() + a.cols()) as u64 * (cfg.c + cfg.r + cfg.s_c) as u64
            }
            ApproxJob::GmrExact { a, c, r } => {
                a.rows() as u64 * a.cols() as u64 * (c.cols() + r.rows()) as u64
            }
            ApproxJob::Cur { a, cfg, .. } => {
                (a.rows() + a.cols()) as u64 * (cfg.c + cfg.r + cfg.s_c + cfg.s_r) as u64
            }
            ApproxJob::StreamingCur { a, cfg, .. } => {
                (a.rows() + a.cols()) as u64 * (cfg.c + cfg.r + cfg.s_c + cfg.s_r) as u64
            }
        }
    }
}

/// Result of a completed job (clonable: the artifact cache hands copies
/// of a stored result to repeated queries, and the batcher fans one
/// computation out to every coalesced waiter).
#[derive(Clone)]
pub enum JobResult {
    /// GMR core matrix X̃ (c×r) plus the sketch sizes used.
    Gmr { x: Mat },
    /// SPSD factors: sampled column indices, C, PSD core; plus observed
    /// kernel-entry count.
    Spsd { idx: Vec<usize>, c: Mat, x: Mat, entries_observed: u64 },
    /// SVD factors.
    Svd { u: Mat, sigma: Vec<f64>, v: Mat },
    /// CUR factors (selected indices + C, U, R).
    Cur { cur: CurDecomposition },
    /// A result computed at a reduced sketch-size tier under load
    /// (graceful degradation), verified with the sketched residual
    /// estimator. `est_rel_residual` is the estimated relative residual
    /// `‖A − CXR‖_F / ‖A‖_F` of the degraded factors (`NaN` when the
    /// kind has no residual estimator). Degraded results are never
    /// cached or persisted — a later uncontended request for the same
    /// key must recompute at full fidelity.
    Degraded { est_rel_residual: f64, inner: Box<JobResult> },
}

impl JobResult {
    pub fn kind(&self) -> &'static str {
        match self {
            JobResult::Gmr { .. } => "gmr",
            JobResult::Spsd { .. } => "spsd",
            JobResult::Svd { .. } => "svd",
            JobResult::Cur { .. } => "cur",
            JobResult::Degraded { inner, .. } => inner.kind(),
        }
    }

    /// Whether this result came from the degraded tier.
    pub fn is_degraded(&self) -> bool {
        matches!(self, JobResult::Degraded { .. })
    }

    /// Output shapes per factor, in the `rows×cols` convention of
    /// [`crate::runtime::artifacts::ManifestEntry`] (index/singular-value
    /// vectors count as `n×1`) — what the artifact cache renders in its
    /// manifest-style inventory.
    pub fn output_shapes(&self) -> Vec<(usize, usize)> {
        match self {
            JobResult::Gmr { x } => vec![x.shape()],
            JobResult::Spsd { idx, c, x, .. } => vec![(idx.len(), 1), c.shape(), x.shape()],
            JobResult::Svd { u, sigma, v } => vec![u.shape(), (sigma.len(), 1), v.shape()],
            JobResult::Cur { cur } => vec![
                (cur.col_idx.len(), 1),
                (cur.row_idx.len(), 1),
                cur.c.shape(),
                cur.u.shape(),
                cur.r.shape(),
            ],
            JobResult::Degraded { inner, .. } => inner.output_shapes(),
        }
    }

    /// Approximate heap size of the result payload — the unit the
    /// artifact cache's byte budget is accounted in (8 bytes per stored
    /// scalar/index; struct overhead is noise at matrix scale).
    pub fn approx_bytes(&self) -> usize {
        self.output_shapes().iter().map(|(r, c)| r * c * 8).sum()
    }

    /// Flatten the payload to 64-bit words, factor by factor in
    /// [`JobResult::output_shapes`] order: floats as IEEE-754 bits
    /// (`f64::to_bits`), indices as plain `u64`, plus one trailing word
    /// for `entries_observed` on SPSD results. Shapes travel separately
    /// (via the cache's manifest line), so the encoding is exactly
    /// `Σ rows·cols` words (+1 for SPSD) — the round-trip partner of
    /// [`JobResult::from_words`]. `Degraded` results are never
    /// persisted; encoding one encodes its inner result.
    pub fn to_words(&self) -> Vec<u64> {
        fn mat(out: &mut Vec<u64>, m: &Mat) {
            out.extend(m.data().iter().map(|v| v.to_bits()));
        }
        let mut w = Vec::new();
        match self {
            JobResult::Gmr { x } => mat(&mut w, x),
            JobResult::Spsd { idx, c, x, entries_observed } => {
                w.extend(idx.iter().map(|&i| i as u64));
                mat(&mut w, c);
                mat(&mut w, x);
                w.push(*entries_observed);
            }
            JobResult::Svd { u, sigma, v } => {
                mat(&mut w, u);
                w.extend(sigma.iter().map(|s| s.to_bits()));
                mat(&mut w, v);
            }
            JobResult::Cur { cur } => {
                w.extend(cur.col_idx.iter().map(|&i| i as u64));
                w.extend(cur.row_idx.iter().map(|&i| i as u64));
                mat(&mut w, &cur.c);
                mat(&mut w, &cur.u);
                mat(&mut w, &cur.r);
            }
            JobResult::Degraded { inner, .. } => return inner.to_words(),
        }
        w
    }

    /// Rebuild a result from its [`JobResult::to_words`] encoding given
    /// the kind tag and per-factor shapes. Returns `None` on any
    /// mismatch (unknown kind, wrong factor count, word count that
    /// disagrees with the shapes) — the warm-start loader treats `None`
    /// as a corrupt entry and skips it.
    pub fn from_words(kind: &str, shapes: &[(usize, usize)], words: &[u64]) -> Option<JobResult> {
        fn mat(words: &mut &[u64], shape: (usize, usize)) -> Option<Mat> {
            let n = shape.0.checked_mul(shape.1)?;
            if words.len() < n {
                return None;
            }
            let (head, tail) = words.split_at(n);
            *words = tail;
            Some(Mat::from_vec(shape.0, shape.1, head.iter().map(|&w| f64::from_bits(w)).collect()))
        }
        fn idx(words: &mut &[u64], n: usize) -> Option<Vec<usize>> {
            if words.len() < n {
                return None;
            }
            let (head, tail) = words.split_at(n);
            *words = tail;
            Some(head.iter().map(|&w| w as usize).collect())
        }
        let mut w = words;
        let result = match kind {
            "gmr" => {
                let [sx] = shapes else { return None };
                JobResult::Gmr { x: mat(&mut w, *sx)? }
            }
            "spsd" => {
                let [si, sc, sx] = shapes else { return None };
                if si.1 != 1 {
                    return None;
                }
                let idx = idx(&mut w, si.0)?;
                let c = mat(&mut w, *sc)?;
                let x = mat(&mut w, *sx)?;
                let [entries_observed] = w else { return None };
                let entries_observed = *entries_observed;
                w = &[];
                JobResult::Spsd { idx, c, x, entries_observed }
            }
            "svd" => {
                let [su, ss, sv] = shapes else { return None };
                if ss.1 != 1 {
                    return None;
                }
                let u = mat(&mut w, *su)?;
                let sigma = mat(&mut w, (ss.0, 1))?.data().to_vec();
                let v = mat(&mut w, *sv)?;
                JobResult::Svd { u, sigma, v }
            }
            "cur" => {
                let [sci, sri, sc, su, sr] = shapes else { return None };
                if sci.1 != 1 || sri.1 != 1 {
                    return None;
                }
                JobResult::Cur {
                    cur: CurDecomposition {
                        col_idx: idx(&mut w, sci.0)?,
                        row_idx: idx(&mut w, sri.0)?,
                        c: mat(&mut w, *sc)?,
                        u: mat(&mut w, *su)?,
                        r: mat(&mut w, *sr)?,
                    },
                }
            }
            _ => return None,
        };
        if w.is_empty() { Some(result) } else { None }
    }
}

/// Sketch family a service config maps to per payload type (dense →
/// Gaussian, sparse → CountSketch, the §6 convention).
pub fn default_kind_for(payload: &MatrixPayload) -> SketchKind {
    match payload {
        MatrixPayload::Dense(_) => SketchKind::Gaussian,
        MatrixPayload::Sparse(_) => SketchKind::Count,
    }
}
