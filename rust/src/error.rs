//! Library-wide error type.

use thiserror::Error;

/// Errors surfaced by the fastgmr library.
#[derive(Error, Debug)]
pub enum FgError {
    #[error("matrix is not positive definite (pivot {pivot}, value {value})")]
    NotPositiveDefinite { pivot: usize, value: f64 },

    #[error("shape mismatch: {context} (expected {expected}, got {got})")]
    ShapeMismatch { context: String, expected: String, got: String },

    #[error("artifact `{name}` not found under {dir} — run `make artifacts`")]
    ArtifactMissing { name: String, dir: String },

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("data error: {0}")]
    Data(String),

    #[error("coordinator error: {0}")]
    Coordinator(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for FgError {
    fn from(e: xla::Error) -> Self {
        FgError::Runtime(e.to_string())
    }
}

/// Library result alias.
pub type Result<T> = std::result::Result<T, FgError>;
