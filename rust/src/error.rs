//! Library-wide error type (hand-rolled `Display`/`Error` impls — the
//! offline image vendors no `thiserror`).

use std::fmt;

/// Errors surfaced by the fastgmr library.
#[derive(Debug)]
pub enum FgError {
    NotPositiveDefinite { pivot: usize, value: f64 },
    ShapeMismatch { context: String, expected: String, got: String },
    ArtifactMissing { name: String, dir: String },
    Runtime(String),
    Config(String),
    Data(String),
    Coordinator(String),
    /// The serving layer's bounded submit queue is at capacity: the
    /// request was shed at admission (load-shedding backpressure)
    /// instead of being queued behind work it would only slow down.
    Overloaded { depth: usize },
    /// A job's deadline elapsed before an executor could complete it —
    /// either it expired while queued or the caller stopped waiting.
    DeadlineExceeded { waited_ms: u64 },
    Io(std::io::Error),
}

impl fmt::Display for FgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FgError::NotPositiveDefinite { pivot, value } => {
                write!(f, "matrix is not positive definite (pivot {pivot}, value {value})")
            }
            FgError::ShapeMismatch { context, expected, got } => {
                write!(f, "shape mismatch: {context} (expected {expected}, got {got})")
            }
            FgError::ArtifactMissing { name, dir } => {
                write!(f, "artifact `{name}` not found under {dir} — run `make artifacts`")
            }
            FgError::Runtime(msg) => write!(f, "runtime error: {msg}"),
            FgError::Config(msg) => write!(f, "config error: {msg}"),
            FgError::Data(msg) => write!(f, "data error: {msg}"),
            FgError::Coordinator(msg) => write!(f, "coordinator error: {msg}"),
            FgError::Overloaded { depth } => {
                write!(f, "server overloaded: submit queue full at depth {depth}; request shed")
            }
            FgError::DeadlineExceeded { waited_ms } => {
                write!(f, "deadline exceeded: job waited {waited_ms} ms without completing")
            }
            FgError::Io(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for FgError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FgError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for FgError {
    fn from(e: std::io::Error) -> Self {
        FgError::Io(e)
    }
}

impl From<xla::Error> for FgError {
    fn from(e: xla::Error) -> Self {
        FgError::Runtime(e.to_string())
    }
}

/// Library result alias.
pub type Result<T> = std::result::Result<T, FgError>;
