//! Library-wide error type (hand-rolled `Display`/`Error` impls — the
//! offline image vendors no `thiserror`).
//!
//! # Transient vs. permanent errors
//!
//! The serving layer's retry machinery classifies every error with
//! [`FgError::is_transient`]. **Transient** errors describe conditions
//! that may clear on their own — a dropped stream read
//! ([`FgError::StreamRead`] with `transient: true`), or an I/O error of
//! kind `Interrupted`/`TimedOut`/`WouldBlock` — and are safe to retry
//! under a [`RetryPolicy`](crate::faults::RetryPolicy). Everything else
//! is **permanent**: retrying a shape mismatch or a non-PD pivot burns
//! executor time reproducing the same failure, so permanent errors
//! surface on the first attempt.

use std::fmt;

/// Errors surfaced by the fastgmr library.
#[derive(Debug)]
pub enum FgError {
    NotPositiveDefinite { pivot: usize, value: f64 },
    ShapeMismatch { context: String, expected: String, got: String },
    ArtifactMissing { name: String, dir: String },
    Runtime(String),
    Config(String),
    Data(String),
    Coordinator(String),
    /// The serving layer's bounded submit queue is at capacity: the
    /// request was shed at admission (load-shedding backpressure)
    /// instead of being queued behind work it would only slow down.
    Overloaded { depth: usize },
    /// A job's deadline elapsed before an executor could complete it —
    /// either it expired while queued or the caller stopped waiting.
    DeadlineExceeded { waited_ms: u64 },
    /// A column-block read failed. `transient: true` marks conditions
    /// that may clear on retry (the reader retries these in place,
    /// without disturbing single-pass sketch state); `false` marks a
    /// dead source.
    StreamRead { context: String, transient: bool },
    /// The per-kind circuit breaker is open: this job kind panicked
    /// repeatedly and the router is failing fast until the cooldown
    /// elapses and a half-open probe succeeds.
    CircuitOpen { kind: String },
    /// A malformed or over-limit wire request (bad frame grammar,
    /// oversized payload, checksum mismatch, truncated frame). Always
    /// permanent: the peer must fix the request, retrying replays the
    /// same bytes.
    Protocol(String),
    Io(std::io::Error),
}

impl FgError {
    /// Whether retrying the failed operation could plausibly succeed.
    /// See the [module docs](self) for the taxonomy.
    pub fn is_transient(&self) -> bool {
        match self {
            FgError::StreamRead { transient, .. } => *transient,
            FgError::Io(e) => matches!(
                e.kind(),
                std::io::ErrorKind::Interrupted
                    | std::io::ErrorKind::TimedOut
                    | std::io::ErrorKind::WouldBlock
            ),
            _ => false,
        }
    }

    /// Variant-preserving duplicate, for fanning a single failure out to
    /// several waiters (`FgError` is not `Clone` because `io::Error` is
    /// not). Every variant round-trips exactly; `Io` keeps its
    /// `ErrorKind` with the message re-wrapped.
    pub fn echo(&self) -> FgError {
        match self {
            FgError::NotPositiveDefinite { pivot, value } => {
                FgError::NotPositiveDefinite { pivot: *pivot, value: *value }
            }
            FgError::ShapeMismatch { context, expected, got } => FgError::ShapeMismatch {
                context: context.clone(),
                expected: expected.clone(),
                got: got.clone(),
            },
            FgError::ArtifactMissing { name, dir } => {
                FgError::ArtifactMissing { name: name.clone(), dir: dir.clone() }
            }
            FgError::Runtime(m) => FgError::Runtime(m.clone()),
            FgError::Config(m) => FgError::Config(m.clone()),
            FgError::Data(m) => FgError::Data(m.clone()),
            FgError::Coordinator(m) => FgError::Coordinator(m.clone()),
            FgError::Overloaded { depth } => FgError::Overloaded { depth: *depth },
            FgError::DeadlineExceeded { waited_ms } => {
                FgError::DeadlineExceeded { waited_ms: *waited_ms }
            }
            FgError::StreamRead { context, transient } => {
                FgError::StreamRead { context: context.clone(), transient: *transient }
            }
            FgError::CircuitOpen { kind } => FgError::CircuitOpen { kind: kind.clone() },
            FgError::Protocol(m) => FgError::Protocol(m.clone()),
            FgError::Io(e) => FgError::Io(std::io::Error::new(e.kind(), e.to_string())),
        }
    }
}

/// Best-effort extraction of a panic payload's message (the payload of
/// `catch_unwind`). `panic!("...")` yields `&'static str`; formatted
/// panics yield `String`; anything else gets a placeholder.
pub fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl fmt::Display for FgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FgError::NotPositiveDefinite { pivot, value } => {
                write!(f, "matrix is not positive definite (pivot {pivot}, value {value})")
            }
            FgError::ShapeMismatch { context, expected, got } => {
                write!(f, "shape mismatch: {context} (expected {expected}, got {got})")
            }
            FgError::ArtifactMissing { name, dir } => {
                write!(f, "artifact `{name}` not found under {dir} — run `make artifacts`")
            }
            FgError::Runtime(msg) => write!(f, "runtime error: {msg}"),
            FgError::Config(msg) => write!(f, "config error: {msg}"),
            FgError::Data(msg) => write!(f, "data error: {msg}"),
            FgError::Coordinator(msg) => write!(f, "coordinator error: {msg}"),
            FgError::Overloaded { depth } => {
                write!(f, "server overloaded: submit queue full at depth {depth}; request shed")
            }
            FgError::DeadlineExceeded { waited_ms } => {
                write!(f, "deadline exceeded: job waited {waited_ms} ms without completing")
            }
            FgError::StreamRead { context, transient } => {
                let class = if *transient { "transient" } else { "permanent" };
                write!(f, "{class} stream read error: {context}")
            }
            FgError::CircuitOpen { kind } => {
                write!(
                    f,
                    "circuit breaker open for kind `{kind}`: failing fast after repeated \
                     executor panics"
                )
            }
            FgError::Protocol(msg) => write!(f, "protocol error: {msg}"),
            FgError::Io(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for FgError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FgError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for FgError {
    fn from(e: std::io::Error) -> Self {
        FgError::Io(e)
    }
}

impl From<xla::Error> for FgError {
    fn from(e: xla::Error) -> Self {
        FgError::Runtime(e.to_string())
    }
}

/// Library result alias.
pub type Result<T> = std::result::Result<T, FgError>;
