fn main() {
    if let Err(e) = fastgmr::cli::main_entry() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
