fn main() -> anyhow::Result<()> {
    fastgmr::cli::main_entry()
}
