//! `fastgmr` launcher binary — thin shell around [`fastgmr::cli`]: parse
//! argv, dispatch the subcommand, map any [`fastgmr::FgError`] to a
//! nonzero exit.

fn main() {
    if let Err(e) = fastgmr::cli::main_entry() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
