//! Integration tests over the AOT artifacts: load every artifact through
//! the PJRT engine, check golden outputs, and verify that the PJRT
//! backend agrees with the CPU backend on the hot-path ops.
//!
//! Skipped (cleanly, with a message) when `artifacts/` hasn't been built.

use fastgmr::compute::{Backend, CpuBackend, PjrtBackend};
use fastgmr::linalg::Mat;
use fastgmr::rng::rng;
use fastgmr::runtime::Engine;
use std::sync::Arc;

fn engine() -> Option<Arc<Engine>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Engine::new(&dir) {
        Ok(e) => Some(Arc::new(e)),
        Err(_) => {
            eprintln!("artifacts/ not built — run `make artifacts`; skipping");
            None
        }
    }
}

#[test]
fn all_goldens_pass() {
    let Some(engine) = engine() else { return };
    let results = engine.verify_goldens().expect("golden verification ran");
    assert!(!results.is_empty(), "no goldens found");
    for (name, err) in &results {
        // f32 end-to-end; cholesky solves amplify to ~1e-4 relative.
        assert!(err < &2e-3, "golden mismatch for {name}: max rel err {err}");
    }
    eprintln!("verified {} artifacts", results.len());
}

#[test]
fn pjrt_backend_matches_cpu_backend() {
    let Some(engine) = engine() else { return };
    let pjrt = PjrtBackend::new(engine);
    let cpu = CpuBackend;
    let mut r = rng(1);

    // sketch_apply at a non-tile shape (exercises padding).
    let s = Mat::randn(100, 900, &mut r);
    let a = Mat::randn(900, 200, &mut r);
    let got = pjrt.sketch_apply(&s, &a).unwrap();
    let want = cpu.sketch_apply(&s, &a).unwrap();
    assert_eq!(got.shape(), want.shape());
    let denom = want.fro_norm().max(1.0);
    assert!(
        fastgmr::linalg::fro_norm_diff(&got, &want) / denom < 1e-5,
        "sketch_apply mismatch"
    );

    // rbf_block.
    let xi = Mat::randn(70, 100, &mut r);
    let xj = Mat::randn(90, 100, &mut r);
    let got = pjrt.rbf_block(&xi, &xj, 0.25).unwrap();
    let want = cpu.rbf_block(&xi, &xj, 0.25).unwrap();
    assert!(
        fastgmr::linalg::fro_norm_diff(&got, &want) / want.fro_norm() < 1e-5,
        "rbf_block mismatch"
    );

    // twoside.
    let sc = Mat::randn(150, 1200, &mut r);
    let al = Mat::randn(1200, 300, &mut r);
    let sr = Mat::randn(150, 300, &mut r);
    let got = pjrt.twoside_sketch(&sc, &al, &sr).unwrap();
    let want = cpu.twoside_sketch(&sc, &al, &sr).unwrap();
    assert!(
        fastgmr::linalg::fro_norm_diff(&got, &want) / want.fro_norm() < 1e-4,
        "twoside mismatch"
    );

    // stream_update.
    let a_l = Mat::randn(1500, 400, &mut r);
    let om = Mat::randn(400, 50, &mut r);
    let psi = Mat::randn(40, 1500, &mut r);
    let sc2 = Mat::randn(120, 1500, &mut r);
    let sr2 = Mat::randn(120, 400, &mut r);
    let (gc, gr, gm) = pjrt.stream_update(&a_l, &om, &psi, &sc2, &sr2).unwrap();
    let (wc, wr, wm) = cpu.stream_update(&a_l, &om, &psi, &sc2, &sr2).unwrap();
    for (g, w, tag) in [(&gc, &wc, "C"), (&gr, &wr, "R"), (&gm, &wm, "M")] {
        assert_eq!(g.shape(), w.shape(), "{tag} shape");
        assert!(
            fastgmr::linalg::fro_norm_diff(g, w) / w.fro_norm() < 1e-4,
            "stream_update {tag} mismatch"
        );
    }
}

#[test]
fn gmr_solve_artifact_matches_rust_solver() {
    let Some(engine) = engine() else { return };
    let graph = engine.load("gmr_solve_192x64x192x64").expect("artifact present");
    let mut r = rng(5);
    let sc_c = Mat::randn(192, 64, &mut r);
    let a_tilde = Mat::randn(192, 192, &mut r);
    let r_sr = Mat::randn(64, 192, &mut r);
    let out = graph.run(&[&sc_c, &a_tilde, &r_sr]).unwrap();
    assert_eq!(out.len(), 1);
    let want = fastgmr::gmr::solve_core(&sc_c, &a_tilde, &r_sr);
    let rel = fastgmr::linalg::fro_norm_diff(&out[0], &want) / want.fro_norm();
    assert!(rel < 1e-3, "gmr_solve artifact vs rust: rel err {rel}");
}

#[test]
fn executable_cache_returns_same_instance() {
    let Some(engine) = engine() else { return };
    let g1 = engine.load("rbf_128x128x128").unwrap();
    let g2 = engine.load("rbf_128x128x128").unwrap();
    assert!(Arc::ptr_eq(&g1, &g2), "cache must reuse the compiled executable");
}

#[test]
fn shape_mismatch_is_rejected() {
    let Some(engine) = engine() else { return };
    let graph = engine.load("rbf_128x128x128").unwrap();
    let bad = Mat::zeros(64, 128);
    let sig = Mat::from_vec(1, 1, vec![0.5]);
    let err = graph.run(&[&bad, &bad, &sig]).unwrap_err();
    assert!(err.to_string().contains("128x128"), "got: {err}");
}
