//! End-to-end integration tests exercising the full stack on small
//! problems (no artifacts required — the CPU backend path).

use fastgmr::coordinator::{PipelineConfig, StreamPipeline};
use fastgmr::data::{synth_dense, SpectrumKind};
use fastgmr::gmr::{relative_regret, solve_exact, solve_fast, FastGmrConfig, Input};
use fastgmr::linalg::{matmul, Mat};
use fastgmr::rng::rng;
use fastgmr::sketch::SketchKind;
use fastgmr::spsd::{error_ratio, faster_spsd, DenseKernelOracle, FasterSpsdConfig};
use fastgmr::svdstream::fast::FastSpSvdSketches;
use fastgmr::svdstream::source::DenseColumnStream;
use fastgmr::svdstream::FastSpSvdConfig;

/// Full Fast-GMR flow on a Figure-1-shaped problem (shrunk): error ratio
/// must decay as sketch size grows, matching the paper's qualitative
/// claim.
#[test]
fn fig1_shape_holds_in_miniature() {
    let mut r = rng(1);
    let a = synth_dense(400, 300, 40, SpectrumKind::Exponential { base: 0.9 }, 0.02, &mut r);
    let (c_dim, r_dim) = (20, 20);
    let g_c = Mat::randn(300, c_dim, &mut r);
    let c = matmul(&a, &g_c);
    let g_r = Mat::randn(r_dim, 400, &mut r);
    let rr = matmul(&g_r, &a);
    let exact = solve_exact(Input::Dense(&a), &c, &rr);

    let mut ratios = Vec::new();
    for &mult in &[2usize, 6, 12] {
        let mut acc = 0.0;
        let trials = 4;
        for t in 0..trials {
            let mut rt = rng(100 + mult as u64 * 17 + t);
            let cfg = FastGmrConfig::gaussian(mult * c_dim, mult * r_dim);
            let sol = solve_fast(Input::Dense(&a), &c, &rr, &cfg, &mut rt);
            acc += relative_regret(Input::Dense(&a), &c, &rr, &sol.x, &exact.x);
        }
        ratios.push(acc / trials as f64);
    }
    assert!(ratios[2] < ratios[0], "error ratio must decay with a: {ratios:?}");
    assert!(ratios[2] < 0.05, "a=12 should be near-exact: {ratios:?}");
}

/// Full Algorithm-2 flow on a Figure-2-shaped kernel problem.
#[test]
fn fig2_shape_holds_in_miniature() {
    let mut r = rng(2);
    let x = fastgmr::data::synth_clustered(300, 12, 8, 0.45, &mut r);
    let sigma = fastgmr::data::calibrate_sigma(&x, 15, 0.85, &mut r);
    let k = fastgmr::data::rbf_kernel(&x, sigma);
    let oracle = DenseKernelOracle { k: &k };
    let c_dim = 30; // 2k with k=15
    let sol = faster_spsd(&oracle, &FasterSpsdConfig { c: c_dim, s: 10 * c_dim }, &mut r);
    let e_faster = error_ratio(&k, &sol.c, &sol.x);
    let nys = fastgmr::spsd::nystrom_core(&sol.c, &sol.idx);
    let e_nys = error_ratio(&k, &sol.c, &nys);
    let opt = fastgmr::spsd::optimal_core(&oracle, &sol.c);
    let e_opt = error_ratio(&k, &sol.c, &opt);
    assert!(
        e_opt <= e_faster && e_faster <= e_nys * 1.05 + 1e-9,
        "ordering violated: opt {e_opt}, faster {e_faster}, nystrom {e_nys}"
    );
    assert!(e_faster < e_opt + 0.08, "faster should be near optimal at s=10c");
}

/// Coordinator pipeline + Algorithm 3 against the paper's single-pass
/// guarantee on a small dense stream.
#[test]
fn streaming_pipeline_end_to_end() {
    let mut r = rng(3);
    let a = synth_dense(250, 220, 30, SpectrumKind::Exponential { base: 0.75 }, 0.01, &mut r);
    let cfg = FastSpSvdConfig::paper(6, 5, SketchKind::Gaussian);
    let sketches = FastSpSvdSketches::draw(&cfg, 250, 220, &mut r);
    let pipeline = StreamPipeline::new(PipelineConfig { workers: 2, queue_depth: 3 });
    let mut stream = DenseColumnStream::new(&a, 32);
    let res = pipeline.run(&mut stream, &cfg, &sketches).unwrap();

    // Error ratio against ‖A − A_k‖.
    let ak = {
        let svd = fastgmr::linalg::svd_randomized(&a, 6, 10, 6, &mut r);
        let top: f64 = svd.s.iter().map(|s| s * s).sum();
        (a.fro_norm_sq() - top).max(0.0).sqrt()
    };
    let ratio = fastgmr::svdstream::error_ratio(&a, &res, ak);
    assert!(ratio < 0.35, "pipeline SP-SVD error ratio {ratio}");
    // Single-pass accounting.
    assert_eq!(res.blocks, (220 + 31) / 32);
}

/// The router serves mixed workloads without deadlock and keeps metrics.
#[test]
fn router_mixed_workload() {
    use fastgmr::coordinator::{jobs::MatrixPayload, ApproxJob, JobResult, Router};
    let router = Router::new(2);
    let mut r = rng(4);
    let mut handles = Vec::new();
    for seed in 0..6u64 {
        let a = synth_dense(100, 80, 15, SpectrumKind::Exponential { base: 0.85 }, 0.02, &mut r);
        let g_c = Mat::randn(80, 8, &mut r);
        let c = matmul(&a, &g_c);
        let g_r = Mat::randn(6, 100, &mut r);
        let rr = matmul(&g_r, &a);
        handles.push(router.submit(ApproxJob::Gmr {
            a: MatrixPayload::Dense(a),
            c,
            r: rr,
            cfg: FastGmrConfig::gaussian(40, 40),
            seed,
        }));
        let x = Mat::randn(120, 10, &mut r);
        handles.push(router.submit(ApproxJob::SpsdKernel { x, sigma: 0.3, c: 8, s: 30, seed }));
    }
    let mut gmr = 0;
    let mut spsd = 0;
    for h in handles {
        match h.wait().unwrap() {
            JobResult::Gmr { .. } => gmr += 1,
            JobResult::Spsd { .. } => spsd += 1,
            _ => unreachable!(),
        }
    }
    assert_eq!((gmr, spsd), (6, 6));
}
