//! Offline stub of the `xla` PJRT binding.
//!
//! The container image does not vendor the real `xla` crate (it links
//! libpjrt), so this shim provides the exact API surface
//! `fastgmr::runtime` compiles against. Construction of the CPU client
//! succeeds — so manifest loading and error paths behave as in the real
//! build — but compiling or executing a computation returns
//! [`Error::Unavailable`]. The CPU backend remains the production path;
//! swapping this stub for the real crate is a one-line change in
//! `rust/Cargo.toml`.

use std::fmt;

/// Error type mirroring `xla::Error`'s role (everything here is the
/// "runtime not available" case).
#[derive(Debug, Clone)]
pub enum Error {
    /// The operation needs the real PJRT runtime, which this stub build
    /// does not link.
    Unavailable(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => {
                write!(f, "{what}: PJRT/XLA runtime not linked (offline `xla` stub build)")
            }
        }
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error::Unavailable(what.to_string()))
}

/// Host literal (f32 tensors at the PJRT boundary).
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from host data.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    /// Copy out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Device buffer returned by an executable.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Transfer the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; outer vec is per-device.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// The PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    /// CPU client. Succeeds in the stub so callers can still load
    /// manifests and surface precise errors at compile/execute time.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    /// Platform name for logs.
    pub fn platform_name(&self) -> String {
        "cpu (xla stub — PJRT not linked)".to_string()
    }

    /// Compile a computation.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO-text artifact from disk.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_compile_is_unavailable() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let comp = XlaComputation::from_proto(&HloModuleProto);
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("PJRT"));
    }

    #[test]
    fn hlo_parse_reports_unavailable() {
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo.txt").is_err());
    }
}
