//! Custom bench harness (`harness = false`): regenerates every table and
//! figure of the paper. See `fastgmr::bench` for targets and profiles.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    fastgmr::bench::bench_main(&args);
}
