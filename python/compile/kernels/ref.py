"""Pure-jnp reference oracle for every L1 Pallas kernel.

These are the ground truth the pytest suite compares the kernels against
(`assert_allclose`), and they double as readable specifications.
"""

import jax.numpy as jnp


def sketch_matmul_ref(s, a):
    """S · A — the sketch-apply product."""
    return jnp.dot(s, a, preferred_element_type=jnp.float32)


def rbf_block_ref(xi, xj, sigma):
    """RBF kernel tile: K[i, j] = exp(-sigma * ||xi_i - xj_j||^2).

    sigma arrives as a (1, 1) array so the AOT graph signature is
    all-matrix (simplifies the Rust boundary).
    """
    ni = jnp.sum(xi * xi, axis=1, keepdims=True)        # (bi, 1)
    nj = jnp.sum(xj * xj, axis=1, keepdims=True).T      # (1, bj)
    cross = jnp.dot(xi, xj.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(ni + nj - 2.0 * cross, 0.0)
    return jnp.exp(-sigma[0, 0] * d2)


def twoside_sketch_ref(sc, a_l, sr):
    """(S_C · A_L) · S_Rᵀ — fused two-sided sketch of a column block."""
    left = jnp.dot(sc, a_l, preferred_element_type=jnp.float32)
    return jnp.dot(left, sr.T, preferred_element_type=jnp.float32)


def stream_update_ref(a_l, omega_t, psi, sc, sr):
    """Algorithm 3 steps 6-8 for one column block.

    Returns (C_delta, R_block, M_delta):
      C_delta = A_L · Ω̃_slice          (m × c)
      R_block = Ψ̃ · A_L                (r × L)
      M_delta = (S_C · A_L) · S_Rᵀ      (s_c × s_r)
    """
    c_delta = jnp.dot(a_l, omega_t, preferred_element_type=jnp.float32)
    r_block = jnp.dot(psi, a_l, preferred_element_type=jnp.float32)
    m_delta = twoside_sketch_ref(sc, a_l, sr)
    return c_delta, r_block, m_delta


def gmr_solve_ref(sc_c, a_tilde, r_sr, ridge=1e-6):
    """Sketched GMR closed form (Eqn. 3.3) via ridge-stabilized normal
    equations: X̃ = (S_C C)† Ã (R S_Rᵀ)†."""
    gc = sc_c.T @ sc_c + ridge * jnp.eye(sc_c.shape[1], dtype=sc_c.dtype)
    left = jnp.linalg.solve(gc, sc_c.T @ a_tilde)            # c × s_r
    gr = r_sr @ r_sr.T + ridge * jnp.eye(r_sr.shape[0], dtype=r_sr.dtype)
    return jnp.linalg.solve(gr.T, (left @ r_sr.T).T).T       # c × r
