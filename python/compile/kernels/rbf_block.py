"""L1 Pallas kernel: fused RBF kernel tile.

Computes `K[I, J] = exp(-sigma * ||x_i - x_j||^2)` for row blocks of the
data matrix without materializing the distance matrix in HBM: the row
norms, the MXU cross-term matmul, and the VPU exp are fused in one
VMEM-resident tile. This is the production form of Algorithm 2's
"observe only these kernel entries" oracle — the coordinator's
TiledKernelOracle pads requests to this tile shape.

Grid: (bi/BI, bj/BJ); the feature dimension D stays resident (padded to
a multiple of 8 lanes). VMEM per step = BI*D + BJ*D + BI*BJ floats —
with BI=BJ=128 and D≤512 that is ≤ 0.6 MiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BI = 128
BJ = 128


def _kernel(xi_ref, xj_ref, sig_ref, o_ref):
    xi = xi_ref[...]  # (BI, D)
    xj = xj_ref[...]  # (BJ, D)
    ni = jnp.sum(xi * xi, axis=1, keepdims=True)      # (BI, 1)
    nj = jnp.sum(xj * xj, axis=1, keepdims=True).T    # (1, BJ)
    cross = jnp.dot(xi, xj.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(ni + nj - 2.0 * cross, 0.0)
    o_ref[...] = jnp.exp(-sig_ref[0, 0] * d2)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rbf_block(xi, xj, sigma, interpret=True):
    """xi (bi×d), xj (bj×d), sigma (1×1) → K (bi×bj). bi/bj must be tile
    multiples (the AOT wrapper and the Rust batcher pad)."""
    bi, d = xi.shape
    bj, d2 = xj.shape
    assert d == d2, f"feature dims differ: {xi.shape} vs {xj.shape}"
    assert bi % BI == 0 and bj % BJ == 0, f"pad to ({BI},{BJ}) tiles first"
    grid = (bi // BI, bj // BJ)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BI, d), lambda i, j: (i, 0)),
            pl.BlockSpec((BJ, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BI, BJ), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bi, bj), jnp.float32),
        interpret=interpret,
    )(xi, xj, sigma)


def rbf_block_padded(xi, xj, sigma, interpret=True):
    """Pad-to-tile wrapper for ragged block sizes."""
    bi, _ = xi.shape
    bj, _ = xj.shape
    pi = -bi % BI
    pj = -bj % BJ
    xip = jnp.pad(xi, ((0, pi), (0, 0)))
    xjp = jnp.pad(xj, ((0, pj), (0, 0)))
    out = rbf_block(xip, xjp, sigma, interpret=interpret)
    return out[:bi, :bj]
