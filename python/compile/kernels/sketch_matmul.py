"""L1 Pallas kernel: tiled sketch-apply matmul `S · A`.

The sketch-apply product is the hot spot of every algorithm in the paper
(T_sketch in Table 2). On TPU the CountSketch/OSNAP scatter formulation is
hostile to the MXU, so the hardware adaptation (DESIGN.md
§Hardware-Adaptation) materializes the sketch operator densely per tile
and rides the 128x128 systolic array instead — `S` arrives as a dense
(s × m) operand.

BlockSpec schedule: grid over (s/BS, n/BN, m/BM); each step loads an
(BS × BM) tile of S and an (BM × BN) tile of A into VMEM and accumulates
into the (BS × BN) output tile. VMEM footprint = 3 tiles = 3·128·128·4 B
= 192 KiB ≪ 16 MiB, leaving room for double buffering.

interpret=True everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; real-TPU performance is *estimated* in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped tiles.
BS = 128  # rows of S per tile
BM = 128  # contraction tile
BN = 128  # cols of A per tile


def _kernel(s_ref, a_ref, o_ref):
    """One grid step: o += s_tile @ a_tile (accumulate over the k grid)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        s_ref[...], a_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def sketch_matmul(s, a, interpret=True):
    """S (s×m) @ A (m×n) with a Pallas grid. Shapes must tile evenly —
    the AOT wrapper pads; the pytest suite exercises ragged shapes via
    hypothesis against the padded call."""
    sm, m = s.shape
    m2, n = a.shape
    assert m == m2, f"inner dim mismatch: {s.shape} @ {a.shape}"
    assert sm % BS == 0 and m % BM == 0 and n % BN == 0, (
        f"shapes must be multiples of ({BS},{BM},{BN}); pad first: {s.shape} @ {a.shape}"
    )
    grid = (sm // BS, n // BN, m // BM)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BS, BM), lambda i, j, k: (i, k)),
            pl.BlockSpec((BM, BN), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((BS, BN), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((sm, n), jnp.float32),
        interpret=interpret,
    )(s, a)


def sketch_matmul_padded(s, a, interpret=True):
    """Pad-to-tile wrapper for arbitrary shapes (used by tests and the
    generic L2 graphs)."""
    sm, m = s.shape
    _, n = a.shape
    pm = -sm % BS
    pk = -m % BM
    pn = -n % BN
    sp = jnp.pad(s, ((0, pm), (0, pk)))
    ap = jnp.pad(a, ((0, pk), (0, pn)))
    out = sketch_matmul(sp, ap, interpret=interpret)
    return out[:sm, :n]
