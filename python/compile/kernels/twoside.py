"""L1 Pallas kernel: fused two-sided sketch `(S_C · A_L) · S_Rᵀ`.

The M-accumulator update of Algorithm 3 (step 8). Fusing the two matmuls
keeps the intermediate `S_C · A_L` tile in VMEM instead of round-tripping
through HBM — the intermediate is (s_c × L), usually the largest tensor
in the update.

Grid: (s_c/BI, s_r/BJ, L/BK); each step computes
`o[i, j] += (sc_tile @ al_tile) @ sr_tileᵀ` with the (BI × BK)
intermediate held in registers/VMEM. The contraction over the m
dimension (rows of A_L) stays whole per tile: A_L blocks are thin
(m ≤ 2048 rows per stream tile), so a full column strip of A_L fits in
VMEM alongside the operands (≤ 2048·128·4 B = 1 MiB).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BI = 128  # s_c tile
BJ = 128  # s_r tile
BK = 128  # L (block-column) tile


def _kernel(sc_ref, al_ref, sr_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (BI × m) @ (m × BK) -> intermediate in VMEM, then @ (BK × BJ).
    left = jnp.dot(sc_ref[...], al_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] += jnp.dot(left, sr_ref[...].T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def twoside_sketch(sc, a_l, sr, interpret=True):
    """sc (s_c×m), a_l (m×L), sr (s_r×L) → (s_c×s_r)."""
    s_c, m = sc.shape
    m2, ll = a_l.shape
    s_r, ll2 = sr.shape
    assert m == m2 and ll == ll2, f"shape mismatch: {sc.shape}, {a_l.shape}, {sr.shape}"
    assert s_c % BI == 0 and s_r % BJ == 0 and ll % BK == 0, "pad to tiles first"
    grid = (s_c // BI, s_r // BJ, ll // BK)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BI, m), lambda i, j, k: (i, 0)),
            pl.BlockSpec((m, BK), lambda i, j, k: (0, k)),
            pl.BlockSpec((BJ, BK), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((BI, BJ), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s_c, s_r), jnp.float32),
        interpret=interpret,
    )(sc, a_l, sr)


def twoside_sketch_padded(sc, a_l, sr, interpret=True):
    """Pad-to-tile wrapper."""
    s_c, m = sc.shape
    _, ll = a_l.shape
    s_r, _ = sr.shape
    pi = -s_c % BI
    pj = -s_r % BJ
    pk = -ll % BK
    scp = jnp.pad(sc, ((0, pi), (0, 0)))
    alp = jnp.pad(a_l, ((0, 0), (0, pk)))
    srp = jnp.pad(sr, ((0, pj), (0, pk)))
    out = twoside_sketch(scp, alp, srp, interpret=interpret)
    return out[:s_c, :s_r]
