"""L2: JAX compute graphs for the Fast-GMR system, calling the L1 Pallas
kernels. These are the functions `aot.py` lowers to HLO-text artifacts;
they never run at request time.

Graphs
------
* ``stream_update`` — Algorithm 3 steps 6–8 for one column block.
* ``gmr_solve`` — the sketched GMR closed form (Eqn. 3.3) via
  Cholesky-based normal equations (`lax.linalg` ops lower to first-class
  HLO when lowered for the TPU platform — see aot.py).
* ``sketch_block`` — generic sketch-apply `S · A`.
* ``rbf`` — RBF kernel tile (Algorithm 2's entry oracle).

All graphs return tuples (lowered with return_tuple=True; the Rust engine
unpacks with `to_tuple`).
"""

import jax.numpy as jnp
from jax import lax

from .kernels.rbf_block import rbf_block_padded
from .kernels.sketch_matmul import sketch_matmul_padded
from .kernels.twoside import twoside_sketch_padded


def sketch_block(s, a):
    """`S · A` through the L1 tiled-matmul kernel."""
    return (sketch_matmul_padded(s, a),)


def rbf(xi, xj, sigma):
    """RBF kernel tile through the L1 fused kernel."""
    return (rbf_block_padded(xi, xj, sigma),)


def twoside(sc, a_l, sr):
    """Fused `(S_C · A_L) · S_Rᵀ` through the L1 kernel."""
    return (twoside_sketch_padded(sc, a_l, sr),)


def stream_update(a_l, omega_t, psi, sc, sr):
    """One streaming update of Algorithm 3 (steps 6–8).

    a_l     : (m, L)   column block of A
    omega_t : (L, c)   slice of Ω̃ for these columns
    psi     : (r, m)   dense Ψ̃ (hardware adaptation: OSNAP scatter →
                        dense MXU matmul, DESIGN.md §Hardware-Adaptation)
    sc      : (s_c, m) dense S_C
    sr      : (s_r, L) slice of S_R for these columns

    Returns (C_delta, R_block, M_delta):
      C_delta = A_L · Ω̃_slice, R_block = Ψ̃ · A_L,
      M_delta = (S_C · A_L) · S_Rᵀ.
    """
    c_delta = sketch_matmul_padded(a_l, omega_t)
    r_block = sketch_matmul_padded(psi, a_l)
    m_delta = twoside_sketch_padded(sc, a_l, sr)
    return (c_delta, r_block, m_delta)


def _chol_solve_spd(g, b, ridge):
    """Solve (g + ridge·I) x = b via Cholesky (HLO-native ops only)."""
    n = g.shape[0]
    l = lax.linalg.cholesky(g + ridge * jnp.eye(n, dtype=g.dtype))
    y = lax.linalg.triangular_solve(l, b, left_side=True, lower=True)
    return lax.linalg.triangular_solve(l, y, left_side=True, lower=True, transpose_a=True)


def gmr_solve(sc_c, a_tilde, r_sr):
    """Sketched GMR solve (Algorithm 1 step 4):
    X̃ = (S_C C)† Ã (R S_Rᵀ)† via ridge-stabilized normal equations.

    sc_c    : (s_c, c)
    a_tilde : (s_c, s_r)
    r_sr    : (r, s_r)
    → X̃     : (c, r)
    """
    ridge = jnp.asarray(1e-6, dtype=sc_c.dtype)
    gc = sc_c.T @ sc_c  # (c, c)
    left = _chol_solve_spd(gc, sc_c.T @ a_tilde, ridge)  # (c, s_r)
    gr = r_sr @ r_sr.T  # (r, r)
    # X̃ᵀ = (gr)⁻¹ (r_sr · leftᵀ)
    xt = _chol_solve_spd(gr, r_sr @ left.T, ridge)  # (r, c)
    return (xt.T,)
