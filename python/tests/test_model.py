"""L2 graph tests: shapes, numerics vs the oracle, and lowering checks
(the artifacts must contain no custom-calls — the property that makes
them loadable by xla_extension 0.5.1)."""

import numpy as np
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref

RNG = np.random.default_rng(7)


def randn(*shape):
    return RNG.standard_normal(shape).astype(np.float32)


class TestStreamUpdate:
    def test_matches_ref(self):
        a_l, om_t = randn(96, 40), randn(40, 24)
        psi, sc, sr = randn(16, 96), randn(48, 96), randn(48, 40)
        got = model.stream_update(a_l, om_t, psi, sc, sr)
        want = ref.stream_update_ref(a_l, om_t, psi, sc, sr)
        for g, w in zip(got, want):
            assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-3, atol=1e-4)

    def test_linearity_in_block(self):
        # The update must be linear in A_L (the streaming-accumulation
        # correctness property: sum of block updates == full update).
        shapes = dict(a=(64, 32), om=(32, 8), psi=(8, 64), sc=(24, 64), sr=(24, 32))
        a1, a2 = randn(*shapes["a"]), randn(*shapes["a"])
        om, psi = randn(*shapes["om"]), randn(*shapes["psi"])
        sc, sr = randn(*shapes["sc"]), randn(*shapes["sr"])
        out1 = model.stream_update(a1, om, psi, sc, sr)
        out2 = model.stream_update(a2, om, psi, sc, sr)
        out_sum = model.stream_update(a1 + a2, om, psi, sc, sr)
        for x1, x2, xs in zip(out1, out2, out_sum):
            assert_allclose(np.asarray(x1) + np.asarray(x2), np.asarray(xs), rtol=1e-3, atol=1e-4)


class TestGmrSolve:
    def test_matches_ref_solver(self):
        sc_c, a_t, r_sr = randn(80, 12), randn(80, 60), randn(10, 60)
        (got,) = model.gmr_solve(sc_c, a_t, r_sr)
        want = ref.gmr_solve_ref(sc_c, a_t, r_sr)
        assert got.shape == (12, 10)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)

    def test_solves_consistent_system_exactly(self):
        # When Ã = (S_C C) X (R S_Rᵀ) exactly, the solve must recover X.
        sc_c, r_sr = randn(64, 8), randn(6, 48)
        x_true = randn(8, 6)
        a_t = sc_c @ x_true @ r_sr
        (got,) = model.gmr_solve(sc_c, a_t, r_sr)
        assert_allclose(np.asarray(got), x_true, rtol=1e-2, atol=1e-3)


class TestLowering:
    def test_all_artifacts_lower_without_custom_calls(self):
        for name, fn, shapes in aot.registry():
            specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
            text = aot.to_hlo_text(jax.jit(fn).trace(*specs))
            assert "custom-call" not in text, f"{name} contains custom-calls"
            assert "ENTRY" in text

    def test_registry_shapes_consistent(self):
        # Executing each registry function on its declared shapes works and
        # yields 2-D f32 outputs (what the manifest records).
        for name, fn, shapes in aot.registry():
            inputs = [randn(*s) if s != (1, 1) else np.array([[0.4]], np.float32) for s in shapes]
            outs = fn(*inputs)
            assert isinstance(outs, tuple), name
            for o in outs:
                assert np.asarray(o).ndim == 2, name
                assert np.asarray(o).dtype == np.float32, name


class TestGoldenLayout:
    def test_build_writes_manifest_and_goldens(self, tmp_path):
        # Build a reduced artifact set into a temp dir and validate layout.
        import os

        full = aot.registry
        try:
            aot.registry = lambda: [
                ("sketch_16x16x16", model.sketch_block, [(16, 16), (16, 16)]),
                ("rbf_8x8x4", model.rbf, [(8, 4), (8, 4), (1, 1)]),
            ]
            aot.build(str(tmp_path), check=True)
        finally:
            aot.registry = full
        manifest = (tmp_path / "manifest.txt").read_text()
        assert "graph sketch_16x16x16" in manifest
        assert "graph rbf_8x8x4" in manifest
        for line in manifest.splitlines():
            if not line.startswith("graph"):
                continue
            parts = dict(kv.split("=") for kv in line.split()[2:])
            assert os.path.exists(tmp_path / parts["file"])
            golden = tmp_path / parts["golden"]
            assert golden.exists()
            # Golden length = 4 bytes * (sum inputs + sum outputs).
            def size(spec):
                return sum(int(a) * int(b) for a, b in (s.split("x") for s in spec.split(",")))

            expected = 4 * (size(parts["inputs"]) + size(parts["outputs"]))
            assert golden.stat().st_size == expected
