"""L1 kernel correctness: every Pallas kernel vs the pure-jnp oracle.

hypothesis sweeps shapes (including ragged, tile-straddling ones) and
value scales; assert_allclose against ref.py is the core correctness
signal for the compute layer.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.rbf_block import rbf_block, rbf_block_padded
from compile.kernels.sketch_matmul import sketch_matmul, sketch_matmul_padded
from compile.kernels.twoside import twoside_sketch, twoside_sketch_padded

RNG = np.random.default_rng(42)


def randn(*shape, scale=1.0):
    return (RNG.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------- sketch


class TestSketchMatmul:
    def test_exact_tile_shape(self):
        s, a = randn(128, 128), randn(128, 128)
        assert_allclose(sketch_matmul(s, a), ref.sketch_matmul_ref(s, a), rtol=1e-4, atol=1e-3)

    def test_multi_tile_grid(self):
        s, a = randn(256, 384), randn(384, 256)
        assert_allclose(sketch_matmul(s, a), ref.sketch_matmul_ref(s, a), rtol=1e-4, atol=1e-3)

    def test_rejects_ragged_without_padding(self):
        with pytest.raises(AssertionError):
            sketch_matmul(randn(100, 128), randn(128, 128))

    @settings(max_examples=10, deadline=None)
    @given(
        sm=st.integers(1, 140),
        m=st.integers(1, 140),
        n=st.integers(1, 140),
        scale=st.sampled_from([1e-3, 1.0, 1e3]),
    )
    def test_padded_matches_ref_hypothesis(self, sm, m, n, scale):
        s, a = randn(sm, m, scale=scale), randn(m, n, scale=scale)
        got = sketch_matmul_padded(s, a)
        assert got.shape == (sm, n)
        assert_allclose(got, ref.sketch_matmul_ref(s, a), rtol=1e-3, atol=1e-4 * scale * scale)

    def test_zero_input(self):
        s = np.zeros((128, 128), np.float32)
        a = randn(128, 128)
        assert np.all(np.asarray(sketch_matmul(s, a)) == 0.0)


# ------------------------------------------------------------------ rbf


class TestRbfBlock:
    def test_exact_tile(self):
        xi, xj = randn(128, 64), randn(128, 64)
        sig = np.array([[0.5]], np.float32)
        assert_allclose(rbf_block(xi, xj, sig), ref.rbf_block_ref(xi, xj, sig), rtol=1e-5)

    def test_diagonal_is_one(self):
        x = randn(128, 32)
        sig = np.array([[0.7]], np.float32)
        k = np.asarray(rbf_block(x, x, sig))
        assert_allclose(np.diag(k), np.ones(128), atol=1e-3)

    def test_values_in_unit_interval(self):
        xi, xj = randn(128, 16, scale=3.0), randn(128, 16, scale=3.0)
        sig = np.array([[0.2]], np.float32)
        k = np.asarray(rbf_block(xi, xj, sig))
        assert np.all(k >= 0.0) and np.all(k <= 1.0 + 1e-6)

    @settings(max_examples=10, deadline=None)
    @given(
        bi=st.integers(1, 150),
        bj=st.integers(1, 150),
        d=st.integers(1, 70),
        sigma=st.floats(0.01, 5.0),
    )
    def test_padded_matches_ref_hypothesis(self, bi, bj, d, sigma):
        xi, xj = randn(bi, d), randn(bj, d)
        sig = np.array([[sigma]], np.float32)
        got = rbf_block_padded(xi, xj, sig)
        assert got.shape == (bi, bj)
        assert_allclose(got, ref.rbf_block_ref(xi, xj, sig), rtol=1e-4, atol=1e-6)

    def test_symmetry_when_blocks_equal(self):
        x = randn(130, 24)
        sig = np.array([[0.3]], np.float32)
        k = np.asarray(rbf_block_padded(x, x, sig))
        assert_allclose(k, k.T, atol=1e-6)


# -------------------------------------------------------------- twoside


class TestTwosideSketch:
    def test_exact_tile(self):
        sc, al, sr = randn(128, 200), randn(200, 128), randn(128, 128)
        assert_allclose(
            twoside_sketch(sc, al, sr), ref.twoside_sketch_ref(sc, al, sr), rtol=1e-4
        )

    @settings(max_examples=10, deadline=None)
    @given(
        s_c=st.integers(1, 140),
        m=st.integers(1, 100),
        L=st.integers(1, 140),
        s_r=st.integers(1, 140),
    )
    def test_padded_matches_ref_hypothesis(self, s_c, m, L, s_r):
        sc, al, sr = randn(s_c, m), randn(m, L), randn(s_r, L)
        got = twoside_sketch_padded(sc, al, sr)
        assert got.shape == (s_c, s_r)
        assert_allclose(got, ref.twoside_sketch_ref(sc, al, sr), rtol=1e-3, atol=1e-4)

    def test_accumulation_over_k_grid(self):
        # L spanning multiple BK tiles exercises the accumulate-into-o path.
        sc, al, sr = randn(128, 64), randn(64, 384), randn(128, 384)
        assert_allclose(
            twoside_sketch(sc, al, sr), ref.twoside_sketch_ref(sc, al, sr), rtol=1e-3, atol=1e-3
        )


# ---------------------------------------------------- dtype stability


@pytest.mark.parametrize("scale", [1e-4, 1.0, 1e4])
def test_kernels_stable_across_scales(scale):
    s, a = randn(130, 70, scale=scale), randn(70, 90, scale=scale)
    got = np.asarray(sketch_matmul_padded(s, a))
    want = np.asarray(ref.sketch_matmul_ref(s, a))
    assert np.isfinite(got).all()
    assert_allclose(got, want, rtol=1e-3, atol=1e-5 * scale * scale)


def test_outputs_are_f32():
    s, a = randn(10, 10), randn(10, 10)
    assert sketch_matmul_padded(s, a).dtype == jnp.float32
    sig = np.array([[0.5]], np.float32)
    assert rbf_block_padded(randn(5, 4), randn(6, 4), sig).dtype == jnp.float32
